//! Multinomial logistic regression — the supervised **plugin proof** of
//! the open task layer. This module is written purely against the public
//! `Learner` API: it composes the shared
//! [`EngineOps`](crate::engine::EngineOps) primitives (the dense-score
//! `gemm_bias` kernel), defines no engine methods, and registers through
//! the same [`TaskFactory`] an out-of-tree task would use. Registry name
//! `logreg`, spec `logreg[:d=DIM][:c=CLASSES]` (e.g. `logreg:d=59:c=8`).
//!
//! Model: flat `[w (d*c, row-major), b (c)]` — the same layout family as
//! the SVM, so the default shard-weighted parameter averaging is the
//! correct aggregation rule. One local iteration is one SGD step on the
//! batch's softmax cross-entropy with L2 regularization; the training
//! signal is the regularized mean negative log-likelihood.

use anyhow::Result;

use crate::data::Dataset;
use crate::edge::Hyper;
use crate::engine::{ComputeEngine, EngineOps as _};
use crate::metrics;
use crate::model::learner::{Learner, StepOut};
use crate::model::registry::{TaskFactory, TaskParams};
use crate::util::rng::Rng;

/// The logistic-regression task. Defaults mirror the SVM scenario's data
/// shape (d=59, c=8) so both supervised tasks share the wafer-like corpus.
#[derive(Clone, Copy, Debug)]
pub struct LogRegLearner {
    /// Feature dimension.
    pub d: usize,
    /// Class count.
    pub c: usize,
}

impl Default for LogRegLearner {
    fn default() -> Self {
        LogRegLearner { d: 59, c: 8 }
    }
}

/// The registry factory for `logreg[:d=DIM][:c=CLASSES]`.
pub fn factory() -> TaskFactory {
    TaskFactory {
        name: "logreg",
        about: "multinomial logistic regression (softmax SGD); d=DIM c=CLASSES",
        build: |p: &mut TaskParams| {
            let learner = LogRegLearner {
                d: p.take("d", 59),
                c: p.take("c", 8),
            };
            if learner.d < 1 || learner.c < 2 {
                return Err(anyhow::anyhow!(
                    "logreg needs d >= 1 and c >= 2, got d={} c={}",
                    learner.d,
                    learner.c
                ));
            }
            Ok(Box::new(learner))
        },
    }
}

impl LogRegLearner {
    /// Batch scores via the shared gemm primitive, then in-place softmax.
    /// Returns the mean NLL of the batch and leaves the per-row
    /// probabilities in `scores`.
    fn softmax_scores(
        &self,
        engine: &dyn ComputeEngine,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        scores: &mut Vec<f32>,
    ) -> f64 {
        let (d, c) = (self.d, self.c);
        let n = x.len() / d;
        let (w, b) = params.split_at(d * c);
        scores.clear();
        scores.resize(n * c, 0.0);
        engine.ops().gemm_bias(x, w, b, d, c, scores);
        self.softmax_in_place(scores, y)
    }

    /// In-place softmax over precomputed scores (the post-gemm half of
    /// [`softmax_scores`](Self::softmax_scores)); returns the mean NLL.
    /// The batched path runs one grouped gemm and then this per edge.
    fn softmax_in_place(&self, scores: &mut [f32], y: &[i32]) -> f64 {
        let c = self.c;
        let n = scores.len() / c;
        let mut nll = 0f64;
        for i in 0..n {
            let row = &mut scores[i * c..(i + 1) * c];
            // Max-subtracted softmax for numeric stability.
            let mut max = f32::NEG_INFINITY;
            for &s in row.iter() {
                max = max.max(s);
            }
            let mut z = 0f32;
            for s in row.iter_mut() {
                *s = (*s - max).exp();
                z += *s;
            }
            let inv_z = 1.0 / z;
            for s in row.iter_mut() {
                *s *= inv_z;
            }
            let yi = y[i] as usize;
            debug_assert!(yi < c);
            nll += -(row[yi].max(1e-12) as f64).ln();
        }
        nll / n as f64
    }

    /// Gradient accumulation + SGD update from per-row probabilities
    /// (consumed in place); returns the pre-update squared weight norm
    /// for the regularized signal. Shared verbatim by `local_step` and
    /// `local_step_batch` so both paths are bit-identical.
    fn update_from_probs(
        &self,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        probs: &mut [f32],
        hyper: &Hyper,
    ) -> f64 {
        let (d, c) = (self.d, self.c);
        let n = x.len() / d;
        // Gradient: g[i, k] = p[i, k] - 1{k == y_i}; dw = x^T g / n + reg*w.
        let mut dw = vec![0f32; d * c];
        let mut db = vec![0f32; c];
        for i in 0..n {
            let gi = &mut probs[i * c..(i + 1) * c];
            gi[y[i] as usize] -= 1.0;
            let xi = &x[i * d..(i + 1) * d];
            for (j, &xij) in xi.iter().enumerate() {
                let dwj = &mut dw[j * c..(j + 1) * c];
                for k in 0..c {
                    dwj[k] += xij * gi[k];
                }
            }
            for k in 0..c {
                db[k] += gi[k];
            }
        }

        let (w, b) = params.split_at_mut(d * c);
        let inv_n = 1.0 / n as f32;
        let mut w_sq = 0f64;
        for v in w.iter() {
            w_sq += (*v as f64) * (*v as f64);
        }
        for (wv, g) in w.iter_mut().zip(&dw) {
            *wv -= hyper.lr * (g * inv_n + hyper.reg * *wv);
        }
        for (bv, g) in b.iter_mut().zip(&db) {
            *bv -= hyper.lr * g * inv_n;
        }
        w_sq
    }
}

impl Learner for LogRegLearner {
    fn name(&self) -> &'static str {
        "logreg"
    }

    fn spec(&self) -> String {
        let mut s = "logreg".to_string();
        let dflt = LogRegLearner::default();
        if self.d != dflt.d {
            s.push_str(&format!(":d={}", self.d));
        }
        if self.c != dflt.c {
            s.push_str(&format!(":c={}", self.c));
        }
        s
    }

    fn supervised(&self) -> bool {
        true
    }

    fn metric_name(&self) -> &'static str {
        "accuracy"
    }

    fn param_len(&self) -> usize {
        self.d * self.c + self.c
    }

    fn synth(&self, n: usize, separation: f64, rng: &mut Rng) -> Dataset {
        crate::data::synth::WaferLike {
            n,
            d: self.d,
            classes: self.c,
            separation,
            ..Default::default()
        }
        .generate(rng)
    }

    fn init_params(&self, _train: &Dataset, _rng: &mut Rng) -> Vec<f32> {
        vec![0.0; self.param_len()]
    }

    fn local_step(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<StepOut> {
        let mut probs = Vec::new();
        let nll = self.softmax_scores(engine, params, x, y, &mut probs);
        let w_sq = self.update_from_probs(params, x, y, &mut probs, hyper);
        Ok(StepOut {
            signal: nll + 0.5 * hyper.reg as f64 * w_sq,
        })
    }

    /// Batched stepping: one grouped gemm scores every edge's batch, then
    /// each edge runs the exact softmax + gradient/update tail — bit-equal
    /// to `E` sequential `local_step` calls.
    fn local_step_batch(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [&mut [f32]],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<Vec<StepOut>> {
        let e = params.len();
        if e == 0 {
            return Ok(Vec::new());
        }
        let (d, c) = (self.d, self.c);
        let (px, py) = (x.len() / e, y.len() / e);
        if e == 1 {
            let out = self.local_step(engine, &mut *params[0], x, y, hyper)?;
            return Ok(vec![out]);
        }
        let mut w_all = Vec::with_capacity(e * d * c);
        let mut b_all = Vec::with_capacity(e * c);
        for p in params.iter() {
            let (w, b) = p.split_at(d * c);
            w_all.extend_from_slice(w);
            b_all.extend_from_slice(b);
        }
        let mut scores = vec![0f32; (px / d) * c * e];
        engine
            .ops()
            .gemm_bias_groups(x, &w_all, &b_all, d, c, e, &mut scores);
        let ps = scores.len() / e;
        let mut outs = Vec::with_capacity(e);
        for (g, p) in params.iter_mut().enumerate() {
            let (xg, yg) = (&x[g * px..(g + 1) * px], &y[g * py..(g + 1) * py]);
            let probs = &mut scores[g * ps..(g + 1) * ps];
            let nll = self.softmax_in_place(probs, yg);
            let w_sq = self.update_from_probs(p, xg, yg, probs, hyper);
            outs.push(StepOut {
                signal: nll + 0.5 * hyper.reg as f64 * w_sq,
            });
        }
        Ok(outs)
    }

    fn evaluate(
        &self,
        engine: &dyn ComputeEngine,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<f64> {
        let (d, c) = (self.d, self.c);
        let n = x.len() / d;
        let (w, b) = params.split_at(d * c);
        let mut scores = vec![0f32; n * c];
        engine.ops().gemm_bias(x, w, b, d, c, &mut scores);
        let mut correct = 0f32;
        for i in 0..n {
            let row = &scores[i * c..(i + 1) * c];
            let mut best = 0usize;
            for k in 1..c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            if best == y[i] as usize {
                correct += 1.0;
            }
        }
        Ok(metrics::accuracy(correct, n))
    }

    fn clone_box(&self) -> Box<dyn Learner> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeEngine;

    fn separable(n: usize, lr: &LogRegLearner, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        // label = argmax of the first c features
        let mut x = Vec::with_capacity(n * lr.d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f32> = (0..lr.d).map(|_| rng.normal() as f32).collect();
            let mut best = 0;
            for k in 1..lr.c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            y.push(best as i32);
            x.extend_from_slice(&row);
        }
        (x, y)
    }

    #[test]
    fn zero_weights_nll_is_ln_c() {
        let learner = LogRegLearner { d: 10, c: 4 };
        let engine = NativeEngine::default();
        let mut params = vec![0f32; learner.param_len()];
        let x = vec![1.0f32; 8 * learner.d];
        let y = vec![0i32; 8];
        let hyper = Hyper {
            lr: 0.0,
            reg: 0.0,
            lr_decay: 0.0,
        };
        let out = learner
            .local_step(&engine, &mut params, &x, &y, &hyper)
            .unwrap();
        // Uniform softmax: NLL = ln(c).
        assert!((out.signal - (learner.c as f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn sgd_fits_separable_batch() {
        let learner = LogRegLearner { d: 10, c: 4 };
        let engine = NativeEngine::default();
        let mut rng = Rng::new(0);
        let (x, y) = separable(256, &learner, &mut rng);
        let mut params = vec![0f32; learner.param_len()];
        let hyper = Hyper {
            lr: 0.5,
            reg: 0.0,
            lr_decay: 0.0,
        };
        let first = learner
            .local_step(&engine, &mut params, &x, &y, &hyper)
            .unwrap()
            .signal;
        let mut last = first;
        for _ in 0..80 {
            last = learner
                .local_step(&engine, &mut params, &x, &y, &hyper)
                .unwrap()
                .signal;
        }
        assert!(last < 0.3 * first, "first={first} last={last}");
        let acc = learner.evaluate(&engine, &params, &x, &y).unwrap();
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn regularization_shrinks_weights() {
        let learner = LogRegLearner { d: 10, c: 4 };
        let engine = NativeEngine::default();
        let mut rng = Rng::new(1);
        let (x, y) = separable(64, &learner, &mut rng);
        let mut run = |reg: f32| {
            let mut params = vec![0f32; learner.param_len()];
            let hyper = Hyper {
                lr: 0.3,
                reg,
                lr_decay: 0.0,
            };
            for _ in 0..10 {
                learner
                    .local_step(&engine, &mut params, &x, &y, &hyper)
                    .unwrap();
            }
            params.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
        };
        assert!(run(0.5) < run(0.0));
    }
}
