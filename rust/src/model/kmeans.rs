//! Mini-batch K-means: the reference (pure-Rust) numerics — the oracle
//! twin of the `kmeans_step`/`kmeans_eval` HLO artifacts, semantics
//! matching python/compile/kernels/ref.py (Lloyd E-step statistics;
//! argmin ties to the lowest index like jnp.argmin) — plus the
//! [`KmeansLearner`] plugging the task into the open [`Learner`] API
//! (registry name `kmeans`, spec `kmeans[:k=CLUSTERS][:d=DIM]`).

use anyhow::Result;

use crate::data::Dataset;
use crate::edge::Hyper;
use crate::engine::{ComputeEngine, KernelArg, OutKind};
use crate::metrics;
use crate::model::learner::{Learner, StepOut};
use crate::model::registry::{TaskFactory, TaskParams};
use crate::model::ModelState;
use crate::util::rng::Rng;

/// K-means shape spec. `k` clusters over `d`-dim points; params are the
/// row-major `[k, d]` centers.
#[derive(Clone, Copy, Debug)]
pub struct KmeansSpec {
    /// Number of clusters.
    pub k: usize,
    /// Feature dimension.
    pub d: usize,
}

impl KmeansSpec {
    /// Flat parameter length (k × d center coordinates).
    pub fn param_len(&self) -> usize {
        self.k * self.d
    }

    /// Random-normal center init (what the paper's t=0 "set the global
    /// model randomly" does).
    pub fn init_state(&self, rng: &mut Rng) -> ModelState {
        let params = (0..self.param_len())
            .map(|_| rng.normal() as f32)
            .collect();
        ModelState::new(params)
    }
}

/// E-step statistics over a batch: (sums [k*d], counts [k], inertia).
pub fn stats(centers: &[f32], x: &[f32], spec: &KmeansSpec) -> (Vec<f32>, Vec<f32>, f32) {
    let (k, d) = (spec.k, spec.d);
    assert_eq!(centers.len(), k * d, "bad centers length");
    let n = x.len() / d;
    let mut sums = vec![0f32; k * d];
    let mut counts = vec![0f32; k];
    let mut inertia = 0f64;
    // Precompute ||c||^2 (matches the kernel's expansion; distances are
    // computed identically so argmin tie behaviour agrees bit-for-bit with
    // the f32 math of the HLO path).
    let cc: Vec<f32> = (0..k)
        .map(|j| {
            centers[j * d..(j + 1) * d]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
        })
        .collect();
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let xx: f32 = xi.iter().map(|v| v * v).sum();
        let mut best = 0usize;
        let mut best_d2 = f32::INFINITY;
        for j in 0..k {
            let cj = &centers[j * d..(j + 1) * d];
            let mut cross = 0f32;
            for t in 0..d {
                cross += xi[t] * cj[t];
            }
            let d2 = xx - 2.0 * cross + cc[j];
            if d2 < best_d2 {
                best_d2 = d2;
                best = j;
            }
        }
        counts[best] += 1.0;
        let sb = &mut sums[best * d..(best + 1) * d];
        for t in 0..d {
            sb[t] += xi[t];
        }
        inertia += best_d2 as f64;
    }
    (sums, counts, inertia as f32)
}

/// Assignment pass for eval: (assignments, inertia). Allocates a fresh
/// output; hot paths reuse a caller buffer via [`assign_into`].
pub fn assign(centers: &[f32], x: &[f32], spec: &KmeansSpec) -> (Vec<i32>, f32) {
    let mut out = Vec::new();
    let inertia = assign_into(centers, x, spec, &mut out);
    (out, inertia)
}

/// Assignment pass into a caller-owned buffer: fills `out` (resized to
/// `n`, reusing its capacity) and returns the inertia. Same numerics as
/// [`assign`] — this is what [`CpuOps::argmin_dist`] runs, honouring the
/// "resized to `n`" contract without a per-call allocation.
///
/// [`CpuOps::argmin_dist`]: crate::engine::CpuOps
pub fn assign_into(centers: &[f32], x: &[f32], spec: &KmeansSpec, out: &mut Vec<i32>) -> f32 {
    let (k, d) = (spec.k, spec.d);
    let n = x.len() / d;
    out.clear();
    out.resize(n, 0);
    assign_slice(centers, x, d, k, out)
}

/// Core assignment kernel over a pre-sized slice: fills `out` (length
/// `n`) and returns the inertia as the f64 left fold of the per-row f32
/// best squared distances, in row order — the numeric contract shared by
/// every assignment entry point.
pub(crate) fn assign_slice(centers: &[f32], x: &[f32], d: usize, k: usize, out: &mut [i32]) -> f32 {
    assert_eq!(centers.len(), k * d, "bad centers length");
    let n = x.len() / d;
    assert_eq!(out.len(), n, "bad assignment buffer length");
    let mut inertia = 0f64;
    let cc: Vec<f32> = (0..k)
        .map(|j| {
            centers[j * d..(j + 1) * d]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
        })
        .collect();
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let xx: f32 = xi.iter().map(|v| v * v).sum();
        let mut best = 0usize;
        let mut best_d2 = f32::INFINITY;
        for j in 0..k {
            let cj = &centers[j * d..(j + 1) * d];
            let mut cross = 0f32;
            for t in 0..d {
                cross += xi[t] * cj[t];
            }
            let d2 = xx - 2.0 * cross + cc[j];
            if d2 < best_d2 {
                best_d2 = d2;
                best = j;
            }
        }
        out[i] = best as i32;
        inertia += best_d2 as f64;
    }
    inertia as f32
}

/// Row-block assignment kernel for the threaded `argmin_dist`: fills the
/// block's assignments and per-row f32 best squared distances (`d2`),
/// WITHOUT folding the inertia — the caller folds all rows sequentially
/// in row order so the threaded total is bit-identical to the scalar
/// path's f64 left fold.
pub(crate) fn assign_block(
    centers: &[f32],
    x: &[f32],
    d: usize,
    k: usize,
    assign: &mut [i32],
    d2_out: &mut [f32],
) {
    let n = x.len() / d;
    assert_eq!(assign.len(), n, "bad assignment block length");
    assert_eq!(d2_out.len(), n, "bad d2 block length");
    assert_eq!(centers.len(), k * d, "bad centers length");
    let cc: Vec<f32> = (0..k)
        .map(|j| {
            centers[j * d..(j + 1) * d]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
        })
        .collect();
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let xx: f32 = xi.iter().map(|v| v * v).sum();
        let mut best = 0usize;
        let mut best_d2 = f32::INFINITY;
        for j in 0..k {
            let cj = &centers[j * d..(j + 1) * d];
            let mut cross = 0f32;
            for t in 0..d {
                cross += xi[t] * cj[t];
            }
            let d2 = xx - 2.0 * cross + cc[j];
            if d2 < best_d2 {
                best_d2 = d2;
                best = j;
            }
        }
        assign[i] = best as i32;
        d2_out[i] = best_d2;
    }
}

/// M-step: centers from accumulated (sums, counts); clusters with zero
/// count keep their previous center (standard empty-cluster handling).
pub fn mstep(centers: &mut [f32], sums: &[f32], counts: &[f32], spec: &KmeansSpec) {
    let (k, d) = (spec.k, spec.d);
    assert_eq!(centers.len(), k * d);
    assert_eq!(sums.len(), k * d);
    assert_eq!(counts.len(), k);
    for j in 0..k {
        if counts[j] > 0.0 {
            let inv = 1.0 / counts[j];
            for t in 0..d {
                centers[j * d + t] = sums[j * d + t] * inv;
            }
        }
    }
}

/// Damped mini-batch M-step update shared by `local_step` and
/// `local_step_batch`: centers move `eta` of the way toward the batch
/// means (empty clusters stay put).
fn damped_mstep(params: &mut [f32], sums: &[f32], counts: &[f32], spec: &KmeansSpec, hyper: &Hyper) {
    let eta = (hyper.lr as f64 * 0.75).clamp(0.0, 1.0) as f32;
    let mut target = params.to_vec();
    mstep(&mut target, sums, counts, spec);
    for (c, t) in params.iter_mut().zip(&target) {
        *c += eta * (*t - *c);
    }
}

/// The K-means task as a [`Learner`] plugin. Defaults mirror the deployed
/// artifact contract (k=3, d=16, batch 64, eval batch 512).
#[derive(Clone, Copy, Debug)]
pub struct KmeansLearner {
    /// Number of clusters.
    pub k: usize,
    /// Feature dimension.
    pub d: usize,
}

impl Default for KmeansLearner {
    fn default() -> Self {
        KmeansLearner { k: 3, d: 16 }
    }
}

impl KmeansLearner {
    fn kspec(&self) -> KmeansSpec {
        KmeansSpec {
            k: self.k,
            d: self.d,
        }
    }

    /// Whether the backend's fused kernel may serve this call — the AOT
    /// artifacts are compiled for FIXED shapes (see the manifest
    /// contract), so a parameterized learner (`kmeans:k=5`) or an
    /// off-contract batch takes the portable path.
    fn fused_ok(&self, engine: &dyn ComputeEngine, kernel: &str, n: usize, batch: usize) -> bool {
        let contract = crate::engine::Shapes::default();
        self.k == contract.km_k
            && self.d == contract.km_d
            && n == batch
            && engine.has_kernel(kernel)
    }
}

/// The registry factory for `kmeans[:k=CLUSTERS][:d=DIM]`.
pub fn factory() -> TaskFactory {
    TaskFactory {
        name: "kmeans",
        about: "mini-batch K-means (damped Lloyd); k=CLUSTERS d=DIM",
        build: |p: &mut TaskParams| {
            let learner = KmeansLearner {
                k: p.take("k", 3),
                d: p.take("d", 16),
            };
            if learner.k < 2 || learner.d < 1 {
                return Err(anyhow::anyhow!(
                    "kmeans needs k >= 2 and d >= 1, got k={} d={}",
                    learner.k,
                    learner.d
                ));
            }
            Ok(Box::new(learner))
        },
    }
}

impl Learner for KmeansLearner {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn spec(&self) -> String {
        let mut s = "kmeans".to_string();
        let dflt = KmeansLearner::default();
        if self.k != dflt.k {
            s.push_str(&format!(":k={}", self.k));
        }
        if self.d != dflt.d {
            s.push_str(&format!(":d={}", self.d));
        }
        s
    }

    fn supervised(&self) -> bool {
        false
    }

    fn metric_name(&self) -> &'static str {
        "F1"
    }

    fn param_len(&self) -> usize {
        self.k * self.d
    }

    fn synth(&self, n: usize, separation: f64, rng: &mut Rng) -> Dataset {
        crate::data::synth::TrafficLike {
            n,
            d: self.d,
            k: self.k,
            separation,
            ..Default::default()
        }
        .generate(rng)
    }

    /// k-means++ seeding over a subsample: spreads the initial centers
    /// across blobs so no cluster begins empty and no policy starts with
    /// collapsed centers (helps every algorithm equally). The RNG
    /// consumption is exactly the legacy coordinator init, so fixed-seed
    /// runs reproduce the pre-plugin traces.
    fn init_params(&self, train: &Dataset, rng: &mut Rng) -> Vec<f32> {
        let spec = self.kspec();
        let sample_n = train.n.min(1024);
        let mut params = Vec::with_capacity(spec.param_len());
        let first = train.row(rng.below(train.n));
        params.extend_from_slice(first);
        let mut d2 = vec![0f64; sample_n];
        for _ in 1..spec.k {
            for (i, slot) in d2.iter_mut().enumerate() {
                let row = train.row(i * train.n / sample_n);
                let mut best = f64::INFINITY;
                for c in 0..params.len() / spec.d {
                    let center = &params[c * spec.d..(c + 1) * spec.d];
                    let dist: f64 = row
                        .iter()
                        .zip(center)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum();
                    best = best.min(dist);
                }
                *slot = best;
            }
            let pick = rng.weighted_choice(&d2).unwrap_or(0);
            params.extend_from_slice(train.row(pick * train.n / sample_n));
        }
        params
    }

    /// Damped mini-batch M-step (Sculley-style online K-means): centers
    /// move a decaying step toward the batch means. Like the SVM's lr
    /// decay, this couples clustering quality to the number of achievable
    /// updates — a full M-step per tiny batch would both thrash and
    /// converge instantly.
    fn local_step(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<StepOut> {
        let _ = y; // unsupervised: labels never reach the learner
        let spec = self.kspec();
        let n = x.len() / self.d;
        let (sums, counts, inertia) = if self.fused_ok(
            engine,
            "kmeans_step",
            n,
            crate::engine::Shapes::default().km_batch,
        ) {
            let c_dims = [self.k, self.d];
            let x_dims = [n, self.d];
            let out = engine.run_kernel(
                "kmeans_step",
                &[
                    KernelArg::F32 { data: params, dims: &c_dims },
                    KernelArg::F32 { data: x, dims: &x_dims },
                ],
                &[OutKind::F32Vec, OutKind::F32Vec, OutKind::Scalar],
            )?;
            let mut it = out.into_iter();
            let sums = it.next().unwrap().into_f32s()?;
            let counts = it.next().unwrap().into_f32s()?;
            let inertia = it.next().unwrap().into_scalar()?;
            (sums, counts, inertia)
        } else {
            stats(params, x, &spec)
        };
        damped_mstep(params, &sums, &counts, &spec, hyper);
        Ok(StepOut {
            signal: inertia as f64,
        })
    }

    /// Batched stepping: one grouped assign + one grouped scatter advance
    /// all `E` edges, then each edge runs its damped M-step — bit-equal
    /// to `E` sequential `local_step` calls (the grouped ops preserve
    /// every within-group accumulation order, and `stats` is exactly
    /// assign followed by scatter). Falls back to the per-edge loop when
    /// the backend ships the fused single-edge kernel.
    fn local_step_batch(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [&mut [f32]],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<Vec<StepOut>> {
        let e = params.len();
        if e == 0 {
            return Ok(Vec::new());
        }
        if e == 1 || engine.has_kernel("kmeans_step") {
            let (px, py) = (x.len() / e, y.len() / e);
            let mut outs = Vec::with_capacity(e);
            for (g, p) in params.iter_mut().enumerate() {
                outs.push(self.local_step(
                    engine,
                    p,
                    &x[g * px..(g + 1) * px],
                    &y[g * py..(g + 1) * py],
                    hyper,
                )?);
            }
            return Ok(outs);
        }
        let spec = self.kspec();
        let (k, d) = (self.k, self.d);
        let mut centers_all = Vec::with_capacity(e * k * d);
        for p in params.iter() {
            centers_all.extend_from_slice(p);
        }
        let mut assign = Vec::new();
        let mut inertia = vec![0f32; e];
        engine
            .ops()
            .argmin_dist_groups(x, &centers_all, d, k, e, &mut assign, &mut inertia);
        let mut sums = vec![0f32; e * k * d];
        let mut counts = vec![0f32; e * k];
        engine
            .ops()
            .scatter_add_groups(x, &assign, d, k, e, &mut sums, &mut counts);
        let mut outs = Vec::with_capacity(e);
        for (g, p) in params.iter_mut().enumerate() {
            damped_mstep(
                p,
                &sums[g * k * d..(g + 1) * k * d],
                &counts[g * k..(g + 1) * k],
                &spec,
                hyper,
            );
            outs.push(StepOut {
                signal: inertia[g] as f64,
            });
        }
        Ok(outs)
    }

    fn evaluate(
        &self,
        engine: &dyn ComputeEngine,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<f64> {
        let n = x.len() / self.d;
        let assignments = if self.fused_ok(
            engine,
            "kmeans_eval",
            n,
            crate::engine::Shapes::default().km_eval_batch,
        ) {
            let c_dims = [self.k, self.d];
            let x_dims = [n, self.d];
            let out = engine.run_kernel(
                "kmeans_eval",
                &[
                    KernelArg::F32 { data: params, dims: &c_dims },
                    KernelArg::F32 { data: x, dims: &x_dims },
                ],
                &[OutKind::I32Vec, OutKind::Scalar],
            )?;
            out.into_iter().next().unwrap().into_i32s()?
        } else {
            assign(params, x, &self.kspec()).0
        };
        Ok(metrics::clustering_f1(&assignments, y, self.k))
    }

    fn clone_box(&self) -> Box<dyn Learner> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> KmeansSpec {
        KmeansSpec { k: 3, d: 2 }
    }

    #[test]
    fn stats_counts_sum_to_batch() {
        let s = spec();
        let centers = vec![0.0, 0.0, 5.0, 5.0, -5.0, -5.0];
        let x: Vec<f32> = (0..40).map(|i| (i % 7) as f32 - 3.0).collect();
        let (_, counts, _) = stats(&centers, &x, &s);
        assert_eq!(counts.iter().sum::<f32>(), 20.0);
    }

    #[test]
    fn obvious_clusters_assign_correctly() {
        let s = spec();
        let centers = vec![0.0, 0.0, 10.0, 10.0, -10.0, -10.0];
        let x = vec![0.1, -0.1, 9.9, 10.2, -9.8, -10.1, 0.2, 0.0];
        let (a, inertia) = assign(&centers, &x, &s);
        assert_eq!(a, vec![0, 1, 2, 0]);
        assert!(inertia < 0.5);
    }

    #[test]
    fn mstep_moves_centers_to_means() {
        let s = spec();
        let mut centers = vec![0.0, 0.0, 10.0, 10.0, -10.0, -10.0];
        let sums = vec![2.0, 4.0, 0.0, 0.0, -30.0, -30.0];
        let counts = vec![2.0, 0.0, 3.0];
        mstep(&mut centers, &sums, &counts, &s);
        assert_eq!(&centers[0..2], &[1.0, 2.0]);
        // empty cluster kept its center
        assert_eq!(&centers[2..4], &[10.0, 10.0]);
        assert_eq!(&centers[4..6], &[-10.0, -10.0]);
    }

    #[test]
    fn lloyd_converges_on_separated_blobs() {
        let s = KmeansSpec { k: 3, d: 4 };
        let mut rng = Rng::new(0);
        let means = [[-6.0f32; 4], [0.0; 4], [6.0; 4]];
        let mut x = Vec::new();
        for i in 0..300 {
            let m = &means[i % 3];
            for t in 0..4 {
                x.push(m[t] + rng.normal() as f32 * 0.5);
            }
        }
        let mut state = s.init_state(&mut rng);
        let mut inertias = Vec::new();
        for _ in 0..15 {
            let (sums, counts, inertia) = stats(&state.params, &x, &s);
            inertias.push(inertia);
            mstep(&mut state.params, &sums, &counts, &s);
        }
        assert!(
            inertias.windows(2).all(|w| w[1] <= w[0] + 1e-3),
            "non-monotone: {inertias:?}"
        );
        assert!(inertias.last().unwrap() / inertias[0] < 0.8);
    }

    #[test]
    fn argmin_tie_picks_lowest_index() {
        let s = KmeansSpec { k: 2, d: 1 };
        let centers = vec![1.0, -1.0];
        let x = vec![0.0]; // equidistant
        let (a, _) = assign(&centers, &x, &s);
        assert_eq!(a, vec![0]);
    }
}
