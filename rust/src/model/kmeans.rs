//! Native (pure-Rust) K-means — the oracle twin of the `kmeans_step` /
//! `kmeans_eval` HLO artifacts. Semantics match
//! python/compile/kernels/ref.py (Lloyd E-step statistics; argmin ties to
//! the lowest index like jnp.argmin).

use crate::model::{ModelState, Task};
use crate::util::rng::Rng;

/// K-means shape spec. `k` clusters over `d`-dim points; params are the
/// row-major `[k, d]` centers.
#[derive(Clone, Copy, Debug)]
pub struct KmeansSpec {
    /// Number of clusters.
    pub k: usize,
    /// Feature dimension.
    pub d: usize,
}

impl KmeansSpec {
    /// Flat parameter length (k × d center coordinates).
    pub fn param_len(&self) -> usize {
        self.k * self.d
    }

    /// Random-normal center init (what the paper's t=0 "set the global
    /// model randomly" does).
    pub fn init_state(&self, rng: &mut Rng) -> ModelState {
        let params = (0..self.param_len())
            .map(|_| rng.normal() as f32)
            .collect();
        ModelState {
            task: Task::Kmeans,
            params,
        }
    }
}

/// E-step statistics over a batch: (sums [k*d], counts [k], inertia).
pub fn stats(centers: &[f32], x: &[f32], spec: &KmeansSpec) -> (Vec<f32>, Vec<f32>, f32) {
    let (k, d) = (spec.k, spec.d);
    assert_eq!(centers.len(), k * d, "bad centers length");
    let n = x.len() / d;
    let mut sums = vec![0f32; k * d];
    let mut counts = vec![0f32; k];
    let mut inertia = 0f64;
    // Precompute ||c||^2 (matches the kernel's expansion; distances are
    // computed identically so argmin tie behaviour agrees bit-for-bit with
    // the f32 math of the HLO path).
    let cc: Vec<f32> = (0..k)
        .map(|j| {
            centers[j * d..(j + 1) * d]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
        })
        .collect();
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let xx: f32 = xi.iter().map(|v| v * v).sum();
        let mut best = 0usize;
        let mut best_d2 = f32::INFINITY;
        for j in 0..k {
            let cj = &centers[j * d..(j + 1) * d];
            let mut cross = 0f32;
            for t in 0..d {
                cross += xi[t] * cj[t];
            }
            let d2 = xx - 2.0 * cross + cc[j];
            if d2 < best_d2 {
                best_d2 = d2;
                best = j;
            }
        }
        counts[best] += 1.0;
        let sb = &mut sums[best * d..(best + 1) * d];
        for t in 0..d {
            sb[t] += xi[t];
        }
        inertia += best_d2 as f64;
    }
    (sums, counts, inertia as f32)
}

/// Assignment pass for eval: (assignments, inertia).
pub fn assign(centers: &[f32], x: &[f32], spec: &KmeansSpec) -> (Vec<i32>, f32) {
    let (k, d) = (spec.k, spec.d);
    assert_eq!(centers.len(), k * d, "bad centers length");
    let n = x.len() / d;
    let mut out = Vec::with_capacity(n);
    let mut inertia = 0f64;
    let cc: Vec<f32> = (0..k)
        .map(|j| {
            centers[j * d..(j + 1) * d]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
        })
        .collect();
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let xx: f32 = xi.iter().map(|v| v * v).sum();
        let mut best = 0usize;
        let mut best_d2 = f32::INFINITY;
        for j in 0..k {
            let cj = &centers[j * d..(j + 1) * d];
            let mut cross = 0f32;
            for t in 0..d {
                cross += xi[t] * cj[t];
            }
            let d2 = xx - 2.0 * cross + cc[j];
            if d2 < best_d2 {
                best_d2 = d2;
                best = j;
            }
        }
        out.push(best as i32);
        inertia += best_d2 as f64;
    }
    (out, inertia as f32)
}

/// M-step: centers from accumulated (sums, counts); clusters with zero
/// count keep their previous center (standard empty-cluster handling).
pub fn mstep(centers: &mut [f32], sums: &[f32], counts: &[f32], spec: &KmeansSpec) {
    let (k, d) = (spec.k, spec.d);
    assert_eq!(centers.len(), k * d);
    assert_eq!(sums.len(), k * d);
    assert_eq!(counts.len(), k);
    for j in 0..k {
        if counts[j] > 0.0 {
            let inv = 1.0 / counts[j];
            for t in 0..d {
                centers[j * d + t] = sums[j * d + t] * inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> KmeansSpec {
        KmeansSpec { k: 3, d: 2 }
    }

    #[test]
    fn stats_counts_sum_to_batch() {
        let s = spec();
        let centers = vec![0.0, 0.0, 5.0, 5.0, -5.0, -5.0];
        let x: Vec<f32> = (0..40).map(|i| (i % 7) as f32 - 3.0).collect();
        let (_, counts, _) = stats(&centers, &x, &s);
        assert_eq!(counts.iter().sum::<f32>(), 20.0);
    }

    #[test]
    fn obvious_clusters_assign_correctly() {
        let s = spec();
        let centers = vec![0.0, 0.0, 10.0, 10.0, -10.0, -10.0];
        let x = vec![0.1, -0.1, 9.9, 10.2, -9.8, -10.1, 0.2, 0.0];
        let (a, inertia) = assign(&centers, &x, &s);
        assert_eq!(a, vec![0, 1, 2, 0]);
        assert!(inertia < 0.5);
    }

    #[test]
    fn mstep_moves_centers_to_means() {
        let s = spec();
        let mut centers = vec![0.0, 0.0, 10.0, 10.0, -10.0, -10.0];
        let sums = vec![2.0, 4.0, 0.0, 0.0, -30.0, -30.0];
        let counts = vec![2.0, 0.0, 3.0];
        mstep(&mut centers, &sums, &counts, &s);
        assert_eq!(&centers[0..2], &[1.0, 2.0]);
        // empty cluster kept its center
        assert_eq!(&centers[2..4], &[10.0, 10.0]);
        assert_eq!(&centers[4..6], &[-10.0, -10.0]);
    }

    #[test]
    fn lloyd_converges_on_separated_blobs() {
        let s = KmeansSpec { k: 3, d: 4 };
        let mut rng = Rng::new(0);
        let means = [[-6.0f32; 4], [0.0; 4], [6.0; 4]];
        let mut x = Vec::new();
        for i in 0..300 {
            let m = &means[i % 3];
            for t in 0..4 {
                x.push(m[t] + rng.normal() as f32 * 0.5);
            }
        }
        let mut state = s.init_state(&mut rng);
        let mut inertias = Vec::new();
        for _ in 0..15 {
            let (sums, counts, inertia) = stats(&state.params, &x, &s);
            inertias.push(inertia);
            mstep(&mut state.params, &sums, &counts, &s);
        }
        assert!(
            inertias.windows(2).all(|w| w[1] <= w[0] + 1e-3),
            "non-monotone: {inertias:?}"
        );
        assert!(inertias.last().unwrap() / inertias[0] < 0.8);
    }

    #[test]
    fn argmin_tie_picks_lowest_index() {
        let s = KmeansSpec { k: 2, d: 1 };
        let centers = vec![1.0, -1.0];
        let x = vec![0.0]; // equidistant
        let (a, _) = assign(&centers, &x, &s);
        assert_eq!(a, vec![0]);
    }
}
