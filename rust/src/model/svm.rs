//! Native (pure-Rust) linear multiclass SVM — the oracle twin of the
//! `svm_step`/`svm_eval` HLO artifacts. Semantics match
//! python/compile/kernels/ref.py exactly (Weston–Watkins one-vs-rest hinge,
//! SGD step with L2 regularization); the pjrt_parity integration test
//! asserts per-step numeric agreement.

use crate::model::{ModelState, Task};

/// SVM hyperparameters + shape. `d` features, `c` classes.
#[derive(Clone, Copy, Debug)]
pub struct SvmSpec {
    /// Feature dimension.
    pub d: usize,
    /// Class count.
    pub c: usize,
    /// Learning rate.
    pub lr: f32,
    /// L2 regularization strength.
    pub reg: f32,
}

impl SvmSpec {
    /// Flat parameter length (d × c weights + c biases).
    pub fn param_len(&self) -> usize {
        self.d * self.c + self.c
    }

    /// The zero-initialized model state (paper: random/zero init at t=0).
    pub fn init_state(&self) -> ModelState {
        ModelState::zeros(Task::Svm, self.param_len())
    }
}

/// Views into the flat parameter vector: (w [d*c], b [c]).
pub fn split_params(params: &[f32], d: usize, c: usize) -> (&[f32], &[f32]) {
    assert_eq!(params.len(), d * c + c, "bad svm param length");
    params.split_at(d * c)
}

/// Split a flat parameter buffer into (weights, biases) views.
pub fn split_params_mut(params: &mut [f32], d: usize, c: usize) -> (&mut [f32], &mut [f32]) {
    assert_eq!(params.len(), d * c + c, "bad svm param length");
    params.split_at_mut(d * c)
}

/// scores[i*c + k] = x_i . w[:,k] + b[k]   (w row-major [d, c])
fn scores_into(x: &[f32], w: &[f32], b: &[f32], d: usize, c: usize, out: &mut [f32]) {
    // Monomorphize the deployed class count so the k-loop compiles to a
    // fixed-width packed FMA (C=8 is the artifact contract; other widths
    // take the generic path).
    match c {
        8 => scores_into_const::<8>(x, w, b, d, out),
        4 => scores_into_const::<4>(x, w, b, d, out),
        _ => scores_into_generic(x, w, b, d, c, out),
    }
}

fn scores_into_const<const C: usize>(x: &[f32], w: &[f32], b: &[f32], d: usize, out: &mut [f32]) {
    let n = x.len() / d;
    debug_assert_eq!(out.len(), n * C);
    let b: &[f32; C] = b.try_into().expect("bias width");
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let mut acc = *b;
        for (j, &xij) in xi.iter().enumerate() {
            let wj: &[f32; C] = w[j * C..(j + 1) * C].try_into().unwrap();
            for k in 0..C {
                acc[k] += xij * wj[k];
            }
        }
        out[i * C..(i + 1) * C].copy_from_slice(&acc);
    }
}

fn scores_into_generic(x: &[f32], w: &[f32], b: &[f32], d: usize, c: usize, out: &mut [f32]) {
    let n = x.len() / d;
    debug_assert_eq!(out.len(), n * c);
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let oi = &mut out[i * c..(i + 1) * c];
        oi.copy_from_slice(b);
        // Dense data: no zero-skip branch; the k-loop is a c-wide FMA that
        // the autovectorizer turns into packed ops.
        for (j, &xij) in xi.iter().enumerate() {
            let wj = &w[j * c..(j + 1) * c];
            for k in 0..c {
                oi[k] += xij * wj[k];
            }
        }
    }
}

/// dw += x_i ⊗ g_i with a compile-time class width (packed FMA).
fn rank1_acc<const C: usize>(dw: &mut [f32], xi: &[f32], gi: &[f32]) {
    let g: &[f32; C] = gi.try_into().expect("gradient width");
    for (j, &xij) in xi.iter().enumerate() {
        let dwj: &mut [f32; C] = (&mut dw[j * C..(j + 1) * C]).try_into().unwrap();
        for k in 0..C {
            dwj[k] += xij * g[k];
        }
    }
}

/// One SGD step on a batch; returns the regularized mean hinge loss.
/// Mirrors ref.svm_step_ref / the svm_step HLO artifact.
pub fn step(params: &mut [f32], x: &[f32], y: &[i32], spec: &SvmSpec) -> f32 {
    let (d, c) = (spec.d, spec.c);
    let n = x.len() / d;
    assert_eq!(y.len(), n);
    let mut scores = vec![0f32; n * c];
    {
        let (w, b) = split_params(params, d, c);
        scores_into(x, w, b, d, c, &mut scores);
    }

    // Gradient accumulation: g[i, k] per sample, then dw = x^T g / n + reg*w.
    let mut dw = vec![0f32; d * c];
    let mut db = vec![0f32; c];
    let mut gi = vec![0f32; c]; // reused per sample — no alloc in the loop
    let mut loss_sum = 0f64;
    for i in 0..n {
        let yi = y[i] as usize;
        debug_assert!(yi < c);
        let si = &scores[i * c..(i + 1) * c];
        let sy = si[yi];
        let mut viol_count = 0f32;
        gi.iter_mut().for_each(|v| *v = 0.0);
        for k in 0..c {
            if k == yi {
                continue;
            }
            let margin = 1.0 + si[k] - sy;
            if margin > 0.0 {
                gi[k] = 1.0;
                viol_count += 1.0;
                loss_sum += margin as f64;
            }
        }
        gi[yi] = -viol_count;
        // accumulate dw += x_i^T g_i
        let xi = &x[i * d..(i + 1) * d];
        // Samples with no violations contribute nothing: skip the d*c pass.
        if viol_count == 0.0 {
            continue;
        }
        match c {
            8 => rank1_acc::<8>(&mut dw, xi, &gi),
            4 => rank1_acc::<4>(&mut dw, xi, &gi),
            _ => {
                for (j, &xij) in xi.iter().enumerate() {
                    let dwj = &mut dw[j * c..(j + 1) * c];
                    for k in 0..c {
                        dwj[k] += xij * gi[k];
                    }
                }
            }
        }
        for k in 0..c {
            db[k] += gi[k];
        }
    }

    let (w, b) = split_params_mut(params, d, c);
    let inv_n = 1.0 / n as f32;
    let mut w_sq = 0f64;
    for v in w.iter() {
        w_sq += (*v as f64) * (*v as f64);
    }
    for (wv, g) in w.iter_mut().zip(&dw) {
        *wv -= spec.lr * (g * inv_n + spec.reg * *wv);
    }
    for (bv, g) in b.iter_mut().zip(&db) {
        *bv -= spec.lr * g * inv_n;
    }
    (loss_sum / n as f64 + 0.5 * spec.reg as f64 * w_sq) as f32
}

/// Eval on a batch: (correct count, mean hinge loss). Mirrors svm_eval.
pub fn eval(params: &[f32], x: &[f32], y: &[i32], spec: &SvmSpec) -> (f32, f32) {
    let (d, c) = (spec.d, spec.c);
    let n = x.len() / d;
    assert_eq!(y.len(), n);
    let (w, b) = split_params(params, d, c);
    let mut scores = vec![0f32; n * c];
    scores_into(x, w, b, d, c, &mut scores);
    let mut correct = 0f32;
    let mut loss_sum = 0f64;
    for i in 0..n {
        let si = &scores[i * c..(i + 1) * c];
        let yi = y[i] as usize;
        // argmax (ties -> lowest index, matching jnp.argmax)
        let mut best = 0usize;
        for k in 1..c {
            if si[k] > si[best] {
                best = k;
            }
        }
        if best == yi {
            correct += 1.0;
        }
        let sy = si[yi];
        for k in 0..c {
            if k == yi {
                continue;
            }
            let m = 1.0 + si[k] - sy;
            if m > 0.0 {
                loss_sum += m as f64;
            }
        }
    }
    (correct, (loss_sum / n as f64) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spec() -> SvmSpec {
        SvmSpec {
            d: 10,
            c: 4,
            lr: 0.1,
            reg: 0.0,
        }
    }

    fn separable_batch(rng: &mut Rng, n: usize, s: &SvmSpec) -> (Vec<f32>, Vec<i32>) {
        // label = argmax of first c features
        let mut x = Vec::with_capacity(n * s.d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f32> = (0..s.d).map(|_| rng.normal() as f32).collect();
            let mut best = 0;
            for k in 1..s.c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            y.push(best as i32);
            x.extend_from_slice(&row);
        }
        (x, y)
    }

    #[test]
    fn zero_weights_loss_is_cminus1() {
        let s = spec();
        let mut params = s.init_state().params;
        let x = vec![1.0f32; 8 * s.d];
        let y = vec![0i32; 8];
        let loss = step(&mut params, &x, &y, &s);
        // At w=0: every non-target margin is exactly 1 -> loss = c-1.
        assert!((loss - (s.c as f32 - 1.0)).abs() < 1e-5, "loss {loss}");
    }

    #[test]
    fn training_reduces_loss_and_fits() {
        let s = spec();
        let mut rng = Rng::new(0);
        let (x, y) = separable_batch(&mut rng, 256, &s);
        let mut params = s.init_state().params;
        let first = step(&mut params, &x, &y, &s);
        let mut last = first;
        for _ in 0..60 {
            last = step(&mut params, &x, &y, &s);
        }
        assert!(last < 0.3 * first, "first={first} last={last}");
        let (correct, _) = eval(&params, &x, &y, &s);
        assert!(correct / 256.0 > 0.9, "acc={}", correct / 256.0);
    }

    #[test]
    fn regularization_shrinks_weights() {
        let mut s = spec();
        s.reg = 0.5;
        let mut rng = Rng::new(1);
        let (x, y) = separable_batch(&mut rng, 64, &s);
        let mut params = s.init_state().params;
        for _ in 0..5 {
            step(&mut params, &x, &y, &s);
        }
        let norm_reg: f64 = params.iter().map(|v| (*v as f64).powi(2)).sum();
        let mut params2 = s.init_state().params;
        let s2 = SvmSpec { reg: 0.0, ..s };
        for _ in 0..5 {
            step(&mut params2, &x, &y, &s2);
        }
        let norm_noreg: f64 = params2.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!(norm_reg < norm_noreg);
    }

    #[test]
    fn eval_perfect_classifier() {
        let s = spec();
        // w = identity on the first c features -> picks argmax exactly.
        let mut params = s.init_state().params;
        for k in 0..s.c {
            params[k * s.c + k] = 1.0; // w[k, k] = 1, row-major [d, c]
        }
        let mut rng = Rng::new(2);
        let (x, y) = separable_batch(&mut rng, 128, &s);
        let (correct, _) = eval(&params, &x, &y, &s);
        assert_eq!(correct, 128.0);
    }

    #[test]
    #[should_panic(expected = "bad svm param length")]
    fn bad_param_len_panics() {
        split_params(&[0.0; 7], 2, 3);
    }
}
