//! Multi-class linear SVM: the reference (pure-Rust) numerics — the
//! oracle twin of the `svm_step`/`svm_eval` HLO artifacts, semantics
//! matching python/compile/kernels/ref.py exactly (Weston–Watkins
//! one-vs-rest hinge, SGD step with L2 regularization; the pjrt_parity
//! integration test asserts per-step numeric agreement) — plus the
//! [`SvmLearner`] plugging the task into the open [`Learner`] API
//! (registry name `svm`, spec `svm[:d=DIM][:c=CLASSES]`).

use anyhow::Result;

use crate::data::Dataset;
use crate::edge::Hyper;
use crate::engine::{ComputeEngine, KernelArg, OutKind};
use crate::metrics;
use crate::model::learner::{Learner, StepOut};
use crate::model::registry::{TaskFactory, TaskParams};
use crate::model::ModelState;
use crate::util::rng::Rng;

/// SVM hyperparameters + shape. `d` features, `c` classes.
#[derive(Clone, Copy, Debug)]
pub struct SvmSpec {
    /// Feature dimension.
    pub d: usize,
    /// Class count.
    pub c: usize,
    /// Learning rate.
    pub lr: f32,
    /// L2 regularization strength.
    pub reg: f32,
}

impl SvmSpec {
    /// Flat parameter length (d × c weights + c biases).
    pub fn param_len(&self) -> usize {
        self.d * self.c + self.c
    }

    /// The zero-initialized model state (paper: random/zero init at t=0).
    pub fn init_state(&self) -> ModelState {
        ModelState::zeros(self.param_len())
    }
}

/// Views into the flat parameter vector: (w [d*c], b [c]).
pub fn split_params(params: &[f32], d: usize, c: usize) -> (&[f32], &[f32]) {
    assert_eq!(params.len(), d * c + c, "bad svm param length");
    params.split_at(d * c)
}

/// Split a flat parameter buffer into (weights, biases) views.
pub fn split_params_mut(params: &mut [f32], d: usize, c: usize) -> (&mut [f32], &mut [f32]) {
    assert_eq!(params.len(), d * c + c, "bad svm param length");
    params.split_at_mut(d * c)
}

/// scores[i*c + k] = x_i . w[:,k] + b[k]   (w row-major [d, c]).
/// Also the implementation behind `EngineOps::gemm_bias` — the shared
/// dense-score primitive every learner can compose.
pub(crate) fn scores_into(x: &[f32], w: &[f32], b: &[f32], d: usize, c: usize, out: &mut [f32]) {
    // Monomorphize the deployed class count so the k-loop compiles to a
    // fixed-width packed FMA (C=8 is the artifact contract; other widths
    // take the generic path).
    match c {
        8 => scores_into_const::<8>(x, w, b, d, out),
        4 => scores_into_const::<4>(x, w, b, d, out),
        _ => scores_into_generic(x, w, b, d, c, out),
    }
}

fn scores_into_const<const C: usize>(x: &[f32], w: &[f32], b: &[f32], d: usize, out: &mut [f32]) {
    let n = x.len() / d;
    debug_assert_eq!(out.len(), n * C);
    let b: &[f32; C] = b.try_into().expect("bias width");
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let mut acc = *b;
        for (j, &xij) in xi.iter().enumerate() {
            let wj: &[f32; C] = w[j * C..(j + 1) * C].try_into().unwrap();
            for k in 0..C {
                acc[k] += xij * wj[k];
            }
        }
        out[i * C..(i + 1) * C].copy_from_slice(&acc);
    }
}

fn scores_into_generic(x: &[f32], w: &[f32], b: &[f32], d: usize, c: usize, out: &mut [f32]) {
    let n = x.len() / d;
    debug_assert_eq!(out.len(), n * c);
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let oi = &mut out[i * c..(i + 1) * c];
        oi.copy_from_slice(b);
        // Dense data: no zero-skip branch; the k-loop is a c-wide FMA that
        // the autovectorizer turns into packed ops.
        for (j, &xij) in xi.iter().enumerate() {
            let wj = &w[j * c..(j + 1) * c];
            for k in 0..c {
                oi[k] += xij * wj[k];
            }
        }
    }
}

/// dw += x_i ⊗ g_i with a compile-time class width (packed FMA).
fn rank1_acc<const C: usize>(dw: &mut [f32], xi: &[f32], gi: &[f32]) {
    let g: &[f32; C] = gi.try_into().expect("gradient width");
    for (j, &xij) in xi.iter().enumerate() {
        let dwj: &mut [f32; C] = (&mut dw[j * C..(j + 1) * C]).try_into().unwrap();
        for k in 0..C {
            dwj[k] += xij * g[k];
        }
    }
}

/// One SGD step on a batch; returns the regularized mean hinge loss.
/// Mirrors ref.svm_step_ref / the svm_step HLO artifact.
pub fn step(params: &mut [f32], x: &[f32], y: &[i32], spec: &SvmSpec) -> f32 {
    let (d, c) = (spec.d, spec.c);
    let n = x.len() / d;
    let mut scores = vec![0f32; n * c];
    {
        let (w, b) = split_params(params, d, c);
        scores_into(x, w, b, d, c, &mut scores);
    }
    step_from_scores(params, x, y, &scores, spec)
}

/// The post-gemm tail of [`step`]: hinge gradients + SGD update from
/// precomputed scores. Split out so the batched path can run one grouped
/// gemm for all edges and then this exact tail per edge — same
/// accumulation orders, bit-identical results.
pub(crate) fn step_from_scores(
    params: &mut [f32],
    x: &[f32],
    y: &[i32],
    scores: &[f32],
    spec: &SvmSpec,
) -> f32 {
    let (d, c) = (spec.d, spec.c);
    let n = x.len() / d;
    assert_eq!(y.len(), n);
    assert_eq!(scores.len(), n * c);

    // Gradient accumulation: g[i, k] per sample, then dw = x^T g / n + reg*w.
    let mut dw = vec![0f32; d * c];
    let mut db = vec![0f32; c];
    let mut gi = vec![0f32; c]; // reused per sample — no alloc in the loop
    let mut loss_sum = 0f64;
    for i in 0..n {
        let yi = y[i] as usize;
        debug_assert!(yi < c);
        let si = &scores[i * c..(i + 1) * c];
        let sy = si[yi];
        let mut viol_count = 0f32;
        gi.iter_mut().for_each(|v| *v = 0.0);
        for k in 0..c {
            if k == yi {
                continue;
            }
            let margin = 1.0 + si[k] - sy;
            if margin > 0.0 {
                gi[k] = 1.0;
                viol_count += 1.0;
                loss_sum += margin as f64;
            }
        }
        gi[yi] = -viol_count;
        // accumulate dw += x_i^T g_i
        let xi = &x[i * d..(i + 1) * d];
        // Samples with no violations contribute nothing: skip the d*c pass.
        if viol_count == 0.0 {
            continue;
        }
        match c {
            8 => rank1_acc::<8>(&mut dw, xi, &gi),
            4 => rank1_acc::<4>(&mut dw, xi, &gi),
            _ => {
                for (j, &xij) in xi.iter().enumerate() {
                    let dwj = &mut dw[j * c..(j + 1) * c];
                    for k in 0..c {
                        dwj[k] += xij * gi[k];
                    }
                }
            }
        }
        for k in 0..c {
            db[k] += gi[k];
        }
    }

    let (w, b) = split_params_mut(params, d, c);
    let inv_n = 1.0 / n as f32;
    let mut w_sq = 0f64;
    for v in w.iter() {
        w_sq += (*v as f64) * (*v as f64);
    }
    for (wv, g) in w.iter_mut().zip(&dw) {
        *wv -= spec.lr * (g * inv_n + spec.reg * *wv);
    }
    for (bv, g) in b.iter_mut().zip(&db) {
        *bv -= spec.lr * g * inv_n;
    }
    (loss_sum / n as f64 + 0.5 * spec.reg as f64 * w_sq) as f32
}

/// Eval on a batch: (correct count, mean hinge loss). Mirrors svm_eval.
pub fn eval(params: &[f32], x: &[f32], y: &[i32], spec: &SvmSpec) -> (f32, f32) {
    let (d, c) = (spec.d, spec.c);
    let n = x.len() / d;
    assert_eq!(y.len(), n);
    let (w, b) = split_params(params, d, c);
    let mut scores = vec![0f32; n * c];
    scores_into(x, w, b, d, c, &mut scores);
    let mut correct = 0f32;
    let mut loss_sum = 0f64;
    for i in 0..n {
        let si = &scores[i * c..(i + 1) * c];
        let yi = y[i] as usize;
        // argmax (ties -> lowest index, matching jnp.argmax)
        let mut best = 0usize;
        for k in 1..c {
            if si[k] > si[best] {
                best = k;
            }
        }
        if best == yi {
            correct += 1.0;
        }
        let sy = si[yi];
        for k in 0..c {
            if k == yi {
                continue;
            }
            let m = 1.0 + si[k] - sy;
            if m > 0.0 {
                loss_sum += m as f64;
            }
        }
    }
    (correct, (loss_sum / n as f64) as f32)
}

/// The SVM task as a [`Learner`] plugin. Defaults mirror the deployed
/// artifact contract (d=59, c=8, batch 64, eval batch 512).
#[derive(Clone, Copy, Debug)]
pub struct SvmLearner {
    /// Feature dimension.
    pub d: usize,
    /// Class count.
    pub c: usize,
}

impl Default for SvmLearner {
    fn default() -> Self {
        SvmLearner { d: 59, c: 8 }
    }
}

impl SvmLearner {
    fn spec_of(&self, hyper: &Hyper) -> SvmSpec {
        SvmSpec {
            d: self.d,
            c: self.c,
            lr: hyper.lr,
            reg: hyper.reg,
        }
    }

    /// Whether the backend's fused kernel may serve this call: the AOT
    /// artifacts are compiled for FIXED shapes (the manifest contract),
    /// so a parameterized learner (`svm:d=20:c=4`) or an off-contract
    /// batch must take the portable path instead of feeding wrong-shaped
    /// literals to the executable.
    fn fused_ok(&self, engine: &dyn ComputeEngine, kernel: &str, n: usize, batch: usize) -> bool {
        let contract = crate::engine::Shapes::default();
        self.d == contract.svm_d
            && self.c == contract.svm_c
            && n == batch
            && engine.has_kernel(kernel)
    }
}

/// The registry factory for `svm[:d=DIM][:c=CLASSES]`.
pub fn factory() -> TaskFactory {
    TaskFactory {
        name: "svm",
        about: "multi-class linear SVM (hinge SGD); d=DIM c=CLASSES",
        build: |p: &mut TaskParams| {
            let learner = SvmLearner {
                d: p.take("d", 59),
                c: p.take("c", 8),
            };
            if learner.d < 1 || learner.c < 2 {
                return Err(anyhow::anyhow!(
                    "svm needs d >= 1 and c >= 2, got d={} c={}",
                    learner.d,
                    learner.c
                ));
            }
            Ok(Box::new(learner))
        },
    }
}

impl Learner for SvmLearner {
    fn name(&self) -> &'static str {
        "svm"
    }

    fn spec(&self) -> String {
        let mut s = "svm".to_string();
        let dflt = SvmLearner::default();
        if self.d != dflt.d {
            s.push_str(&format!(":d={}", self.d));
        }
        if self.c != dflt.c {
            s.push_str(&format!(":c={}", self.c));
        }
        s
    }

    fn supervised(&self) -> bool {
        true
    }

    fn metric_name(&self) -> &'static str {
        "accuracy"
    }

    fn param_len(&self) -> usize {
        self.d * self.c + self.c
    }

    fn synth(&self, n: usize, separation: f64, rng: &mut Rng) -> Dataset {
        crate::data::synth::WaferLike {
            n,
            d: self.d,
            classes: self.c,
            separation,
            ..Default::default()
        }
        .generate(rng)
    }

    fn init_params(&self, _train: &Dataset, _rng: &mut Rng) -> Vec<f32> {
        vec![0.0; self.param_len()]
    }

    fn local_step(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<StepOut> {
        let n = x.len() / self.d;
        if self.fused_ok(engine, "svm_step", n, crate::engine::Shapes::default().svm_batch) {
            let (w, b) = params.split_at(self.d * self.c);
            let w_dims = [self.d, self.c];
            let b_dims = [self.c];
            let x_dims = [n, self.d];
            let y_dims = [n];
            let out = engine.run_kernel(
                "svm_step",
                &[
                    KernelArg::F32 { data: w, dims: &w_dims },
                    KernelArg::F32 { data: b, dims: &b_dims },
                    KernelArg::F32 { data: x, dims: &x_dims },
                    KernelArg::I32 { data: y, dims: &y_dims },
                    KernelArg::Scalar(hyper.lr),
                    KernelArg::Scalar(hyper.reg),
                ],
                &[OutKind::F32Vec, OutKind::F32Vec, OutKind::Scalar],
            )?;
            let mut it = out.into_iter();
            let w2 = it.next().unwrap().into_f32s()?;
            let b2 = it.next().unwrap().into_f32s()?;
            let loss = it.next().unwrap().into_scalar()?;
            params[..self.d * self.c].copy_from_slice(&w2);
            params[self.d * self.c..].copy_from_slice(&b2);
            return Ok(StepOut {
                signal: loss as f64,
            });
        }
        let loss = step(params, x, y, &self.spec_of(hyper));
        Ok(StepOut {
            signal: loss as f64,
        })
    }

    /// Batched stepping: stack every edge's weights/biases and batches
    /// into one grouped gemm dispatch, then run the exact per-edge
    /// gradient/update tail — bit-equal to `E` sequential `local_step`
    /// calls. Falls back to the per-edge loop when the backend ships the
    /// fused single-edge kernel.
    fn local_step_batch(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [&mut [f32]],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<Vec<StepOut>> {
        let e = params.len();
        if e == 0 {
            return Ok(Vec::new());
        }
        let (d, c) = (self.d, self.c);
        if e == 1 || engine.has_kernel("svm_step") {
            let (px, py) = (x.len() / e, y.len() / e);
            let mut outs = Vec::with_capacity(e);
            for (g, p) in params.iter_mut().enumerate() {
                outs.push(self.local_step(
                    engine,
                    p,
                    &x[g * px..(g + 1) * px],
                    &y[g * py..(g + 1) * py],
                    hyper,
                )?);
            }
            return Ok(outs);
        }
        let spec = self.spec_of(hyper);
        let mut w_all = Vec::with_capacity(e * d * c);
        let mut b_all = Vec::with_capacity(e * c);
        for p in params.iter() {
            let (w, b) = split_params(p, d, c);
            w_all.extend_from_slice(w);
            b_all.extend_from_slice(b);
        }
        let (px, py) = (x.len() / e, y.len() / e);
        let mut scores = vec![0f32; (px / d) * c * e];
        engine
            .ops()
            .gemm_bias_groups(x, &w_all, &b_all, d, c, e, &mut scores);
        let ps = scores.len() / e;
        let mut outs = Vec::with_capacity(e);
        for (g, p) in params.iter_mut().enumerate() {
            let loss = step_from_scores(
                p,
                &x[g * px..(g + 1) * px],
                &y[g * py..(g + 1) * py],
                &scores[g * ps..(g + 1) * ps],
                &spec,
            );
            outs.push(StepOut {
                signal: loss as f64,
            });
        }
        Ok(outs)
    }

    fn evaluate(
        &self,
        engine: &dyn ComputeEngine,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<f64> {
        let n = x.len() / self.d;
        if self.fused_ok(engine, "svm_eval", n, crate::engine::Shapes::default().svm_eval_batch) {
            let (w, b) = split_params(params, self.d, self.c);
            let w_dims = [self.d, self.c];
            let b_dims = [self.c];
            let x_dims = [n, self.d];
            let y_dims = [n];
            let out = engine.run_kernel(
                "svm_eval",
                &[
                    KernelArg::F32 { data: w, dims: &w_dims },
                    KernelArg::F32 { data: b, dims: &b_dims },
                    KernelArg::F32 { data: x, dims: &x_dims },
                    KernelArg::I32 { data: y, dims: &y_dims },
                ],
                &[OutKind::Scalar, OutKind::Scalar],
            )?;
            let correct = out.into_iter().next().unwrap().into_scalar()?;
            return Ok(metrics::accuracy(correct, y.len()));
        }
        let (correct, _loss) = eval(
            params,
            x,
            y,
            &SvmSpec {
                d: self.d,
                c: self.c,
                lr: 0.0,
                reg: 0.0,
            },
        );
        Ok(metrics::accuracy(correct, y.len()))
    }

    fn clone_box(&self) -> Box<dyn Learner> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SvmSpec {
        SvmSpec {
            d: 10,
            c: 4,
            lr: 0.1,
            reg: 0.0,
        }
    }

    fn separable_batch(rng: &mut Rng, n: usize, s: &SvmSpec) -> (Vec<f32>, Vec<i32>) {
        // label = argmax of first c features
        let mut x = Vec::with_capacity(n * s.d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f32> = (0..s.d).map(|_| rng.normal() as f32).collect();
            let mut best = 0;
            for k in 1..s.c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            y.push(best as i32);
            x.extend_from_slice(&row);
        }
        (x, y)
    }

    #[test]
    fn zero_weights_loss_is_cminus1() {
        let s = spec();
        let mut params = s.init_state().params;
        let x = vec![1.0f32; 8 * s.d];
        let y = vec![0i32; 8];
        let loss = step(&mut params, &x, &y, &s);
        // At w=0: every non-target margin is exactly 1 -> loss = c-1.
        assert!((loss - (s.c as f32 - 1.0)).abs() < 1e-5, "loss {loss}");
    }

    #[test]
    fn training_reduces_loss_and_fits() {
        let s = spec();
        let mut rng = Rng::new(0);
        let (x, y) = separable_batch(&mut rng, 256, &s);
        let mut params = s.init_state().params;
        let first = step(&mut params, &x, &y, &s);
        let mut last = first;
        for _ in 0..60 {
            last = step(&mut params, &x, &y, &s);
        }
        assert!(last < 0.3 * first, "first={first} last={last}");
        let (correct, _) = eval(&params, &x, &y, &s);
        assert!(correct / 256.0 > 0.9, "acc={}", correct / 256.0);
    }

    #[test]
    fn regularization_shrinks_weights() {
        let mut s = spec();
        s.reg = 0.5;
        let mut rng = Rng::new(1);
        let (x, y) = separable_batch(&mut rng, 64, &s);
        let mut params = s.init_state().params;
        for _ in 0..5 {
            step(&mut params, &x, &y, &s);
        }
        let norm_reg: f64 = params.iter().map(|v| (*v as f64).powi(2)).sum();
        let mut params2 = s.init_state().params;
        let s2 = SvmSpec { reg: 0.0, ..s };
        for _ in 0..5 {
            step(&mut params2, &x, &y, &s2);
        }
        let norm_noreg: f64 = params2.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!(norm_reg < norm_noreg);
    }

    #[test]
    fn eval_perfect_classifier() {
        let s = spec();
        // w = identity on the first c features -> picks argmax exactly.
        let mut params = s.init_state().params;
        for k in 0..s.c {
            params[k * s.c + k] = 1.0; // w[k, k] = 1, row-major [d, c]
        }
        let mut rng = Rng::new(2);
        let (x, y) = separable_batch(&mut rng, 128, &s);
        let (correct, _) = eval(&params, &x, &y, &s);
        assert_eq!(correct, 128.0);
    }

    #[test]
    #[should_panic(expected = "bad svm param length")]
    fn bad_param_len_panics() {
        split_params(&[0.0; 7], 2, 3);
    }
}
