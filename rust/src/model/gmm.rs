//! Spherical Gaussian mixture via hard EM — the unsupervised **plugin
//! proof** of the open task layer. Like [`logreg`](crate::model::logreg),
//! this module is written purely against the public `Learner` API: the
//! E-step's accumulation runs on the shared
//! [`EngineOps::scatter_add`](crate::engine::EngineOps::scatter_add)
//! primitive and the task registers through the same [`TaskFactory`] an
//! out-of-tree task would use. Registry name `gmm`, spec
//! `gmm[:k=COMPONENTS][:d=DIM]` (e.g. `gmm:k=3`).
//!
//! Model: flat `[means (k*d, row-major), logvar (k)]` — each component is
//! an isotropic Gaussian `N(μ_j, σ_j² I)`. Means start at farthest-point
//! seeded training rows. One local iteration is one
//! damped hard-EM step on the batch: assign each row to the component
//! maximizing its log-density, then move the assigned means toward the
//! batch means and the log-variances toward the batch's mean squared
//! deviation (the same Sculley-style damping the K-means learner uses, so
//! update counts couple to clustering quality). Aggregation keeps the
//! default shard-weighted parameter averaging — a deliberate
//! approximation for this layout: exact sufficient-statistics merging
//! would weight each component by its per-shard assignment mass and
//! combine variances arithmetically (plus between-shard mean scatter),
//! while averaging log-variances takes a geometric mean. Under roughly
//! shard-proportional assignments the approximation is close, and it
//! keeps the merge bit-compatible with every other learner. The metric
//! is best-permutation clustering F1 of the hard assignments.

use anyhow::Result;

use crate::data::Dataset;
use crate::edge::Hyper;
use crate::engine::{ComputeEngine, EngineOps as _};
use crate::metrics;
use crate::model::learner::{Learner, StepOut};
use crate::model::registry::{TaskFactory, TaskParams};
use crate::util::rng::Rng;

/// Log-variances are clamped to this range so a component grabbing a
/// single point cannot collapse (σ² → 0 sends its density to ∞ and
/// freezes hard EM).
const LOGVAR_RANGE: (f32, f32) = (-6.0, 6.0);

/// The spherical-GMM task. Defaults mirror the K-means scenario's data
/// shape (k=3, d=16) so both unsupervised tasks share the traffic-like
/// corpus.
#[derive(Clone, Copy, Debug)]
pub struct GmmLearner {
    /// Mixture components.
    pub k: usize,
    /// Feature dimension.
    pub d: usize,
}

impl Default for GmmLearner {
    fn default() -> Self {
        GmmLearner { k: 3, d: 16 }
    }
}

/// The registry factory for `gmm[:k=COMPONENTS][:d=DIM]`.
pub fn factory() -> TaskFactory {
    TaskFactory {
        name: "gmm",
        about: "spherical Gaussian mixture via damped hard EM; k=COMPONENTS d=DIM",
        build: |p: &mut TaskParams| {
            let learner = GmmLearner {
                k: p.take("k", 3),
                d: p.take("d", 16),
            };
            if learner.k < 2 || learner.d < 1 {
                return Err(anyhow::anyhow!(
                    "gmm needs k >= 2 and d >= 1, got k={} d={}",
                    learner.k,
                    learner.d
                ));
            }
            Ok(Box::new(learner))
        },
    }
}

impl GmmLearner {
    fn means_len(&self) -> usize {
        self.k * self.d
    }

    /// Hard E-step: per-row argmax of the isotropic log-density
    /// `-½(‖x−μ_j‖²/σ_j² + d·ln σ_j²)` (the `2π` constant is shared by
    /// every component and dropped). Fills `assign` and the per-row
    /// squared distance to the winning mean; returns the mean negative
    /// (shifted) log-likelihood as the training signal.
    fn hard_assign(
        &self,
        params: &[f32],
        x: &[f32],
        assign: &mut Vec<i32>,
        d2_best: &mut Vec<f32>,
    ) -> f64 {
        let (k, d) = (self.k, self.d);
        let (means, logvar) = params.split_at(self.means_len());
        let n = x.len() / d;
        assign.clear();
        d2_best.clear();
        let var: Vec<f32> = logvar.iter().map(|lv| lv.exp()).collect();
        let penalty: Vec<f32> = logvar.iter().map(|lv| d as f32 * lv).collect();
        let mut nll = 0f64;
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            let mut best = 0usize;
            let mut best_ll = f32::NEG_INFINITY;
            let mut best_d2 = 0f32;
            for j in 0..k {
                let mj = &means[j * d..(j + 1) * d];
                let mut d2 = 0f32;
                for t in 0..d {
                    let diff = xi[t] - mj[t];
                    d2 += diff * diff;
                }
                let ll = -0.5 * (d2 / var[j] + penalty[j]);
                if ll > best_ll {
                    best_ll = ll;
                    best = j;
                    best_d2 = d2;
                }
            }
            assign.push(best as i32);
            d2_best.push(best_d2);
            nll += -(best_ll as f64);
        }
        nll / n as f64
    }

    /// Damped M-step tail from accumulated per-component statistics —
    /// shared verbatim by `local_step` and `local_step_batch` so both
    /// paths are bit-identical. Empty components keep their parameters
    /// (standard empty-cluster handling).
    fn damped_update(
        &self,
        params: &mut [f32],
        sums: &[f32],
        counts: &[f32],
        sq: &[f64],
        hyper: &Hyper,
    ) {
        let (k, d) = (self.k, self.d);
        let eta = (hyper.lr as f64 * 0.75).clamp(0.0, 1.0) as f32;
        let (means, logvar) = params.split_at_mut(self.means_len());
        for j in 0..k {
            if counts[j] <= 0.0 {
                continue;
            }
            let inv = 1.0 / counts[j];
            let mj = &mut means[j * d..(j + 1) * d];
            for t in 0..d {
                let target = sums[j * d + t] * inv;
                mj[t] += eta * (target - mj[t]);
            }
            // Batch variance estimate against the pre-update mean.
            let vhat = (sq[j] / (counts[j] as f64 * d as f64)).max(1e-6);
            let target = (vhat.ln() as f32).clamp(LOGVAR_RANGE.0, LOGVAR_RANGE.1);
            logvar[j] += eta * (target - logvar[j]);
        }
    }
}

impl Learner for GmmLearner {
    fn name(&self) -> &'static str {
        "gmm"
    }

    fn spec(&self) -> String {
        let mut s = "gmm".to_string();
        let dflt = GmmLearner::default();
        if self.k != dflt.k {
            s.push_str(&format!(":k={}", self.k));
        }
        if self.d != dflt.d {
            s.push_str(&format!(":d={}", self.d));
        }
        s
    }

    fn supervised(&self) -> bool {
        false
    }

    fn metric_name(&self) -> &'static str {
        "F1"
    }

    fn param_len(&self) -> usize {
        self.means_len() + self.k
    }

    fn synth(&self, n: usize, separation: f64, rng: &mut Rng) -> Dataset {
        crate::data::synth::TrafficLike {
            n,
            d: self.d,
            k: self.k,
            separation,
            ..Default::default()
        }
        .generate(rng)
    }

    /// Farthest-point seeding over a subsample (the deterministic cousin
    /// of the K-means learner's k-means++ init): the first mean is a
    /// random training row, each further mean the subsample row farthest
    /// from every mean so far — so no two components start inside the
    /// same blob. Log-variances start at 0 (σ² = 1).
    fn init_params(&self, train: &Dataset, rng: &mut Rng) -> Vec<f32> {
        let d = self.d;
        let mut params = Vec::with_capacity(self.param_len());
        params.extend_from_slice(train.row(rng.below(train.n)));
        let sample_n = train.n.min(1024);
        for _ in 1..self.k {
            let mut best = (0usize, -1.0f64);
            for i in 0..sample_n {
                let row = train.row(i * train.n / sample_n);
                let mut min_d = f64::INFINITY;
                for c in 0..params.len() / d {
                    let center = &params[c * d..(c + 1) * d];
                    let dist: f64 = row
                        .iter()
                        .zip(center)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum();
                    min_d = min_d.min(dist);
                }
                if min_d > best.1 {
                    best = (i, min_d);
                }
            }
            params.extend_from_slice(train.row(best.0 * train.n / sample_n));
        }
        params.resize(self.param_len(), 0.0);
        params
    }

    fn local_step(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<StepOut> {
        let _ = y; // unsupervised: labels never reach the learner
        let (k, d) = (self.k, self.d);
        let n = x.len() / d;
        let mut assign = Vec::new();
        let mut d2_best = Vec::new();
        let nll = self.hard_assign(params, x, &mut assign, &mut d2_best);

        // M-step statistics on the shared primitives.
        let mut sums = vec![0f32; k * d];
        let mut counts = vec![0f32; k];
        engine
            .ops()
            .scatter_add(x, &assign, d, k, &mut sums, &mut counts);
        let mut sq = vec![0f64; k];
        for i in 0..n {
            sq[assign[i] as usize] += d2_best[i] as f64;
        }

        // Damped updates (the K-means learner's eta).
        self.damped_update(params, &sums, &counts, &sq, hyper);
        Ok(StepOut { signal: nll })
    }

    /// Batched stepping: per-edge hard E-steps fill one stacked
    /// assignment buffer, a single grouped scatter accumulates every
    /// edge's M-step statistics, then each edge runs the exact damped
    /// update tail — bit-equal to `E` sequential `local_step` calls.
    fn local_step_batch(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [&mut [f32]],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<Vec<StepOut>> {
        let _ = y; // unsupervised: labels never reach the learner
        let e = params.len();
        if e == 0 {
            return Ok(Vec::new());
        }
        let (k, d) = (self.k, self.d);
        let px = x.len() / e;
        let pn = px / d;
        if e == 1 {
            let out = self.local_step(engine, &mut *params[0], x, y, hyper)?;
            return Ok(vec![out]);
        }
        let mut assign_all = Vec::with_capacity(e * pn);
        let mut nlls = vec![0f64; e];
        let mut sq_all = vec![0f64; e * k];
        let mut assign = Vec::new();
        let mut d2_best = Vec::new();
        for (g, p) in params.iter().enumerate() {
            nlls[g] = self.hard_assign(p, &x[g * px..(g + 1) * px], &mut assign, &mut d2_best);
            for i in 0..pn {
                sq_all[g * k + assign[i] as usize] += d2_best[i] as f64;
            }
            assign_all.extend_from_slice(&assign);
        }
        let mut sums = vec![0f32; e * k * d];
        let mut counts = vec![0f32; e * k];
        engine
            .ops()
            .scatter_add_groups(x, &assign_all, d, k, e, &mut sums, &mut counts);
        let mut outs = Vec::with_capacity(e);
        for (g, p) in params.iter_mut().enumerate() {
            self.damped_update(
                p,
                &sums[g * k * d..(g + 1) * k * d],
                &counts[g * k..(g + 1) * k],
                &sq_all[g * k..(g + 1) * k],
                hyper,
            );
            outs.push(StepOut { signal: nlls[g] });
        }
        Ok(outs)
    }

    fn evaluate(
        &self,
        _engine: &dyn ComputeEngine,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<f64> {
        let mut assign = Vec::new();
        let mut d2 = Vec::new();
        self.hard_assign(params, x, &mut assign, &mut d2);
        Ok(metrics::clustering_f1(&assign, y, self.k))
    }

    fn clone_box(&self) -> Box<dyn Learner> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeEngine;

    fn blobs(n: usize, d: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let centers = [[-6.0f32; 16], [0.0; 16], [6.0; 16]];
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 3;
            for t in 0..d {
                x.push(centers[c][t] + rng.normal() as f32 * 0.5);
            }
            y.push(c as i32);
        }
        (x, y)
    }

    #[test]
    fn hard_em_recovers_separated_blobs() {
        let learner = GmmLearner::default();
        let engine = NativeEngine::default();
        let mut rng = Rng::new(3);
        let (x, y) = blobs(300, learner.d, &mut rng);
        let ds = Dataset::new(x.clone(), y.clone(), learner.d);
        let mut params = learner.init_params(&ds, &mut rng);
        let hyper = Hyper {
            lr: 0.6,
            reg: 0.0,
            lr_decay: 0.0,
        };
        let first = learner
            .local_step(&engine, &mut params, &x, &y, &hyper)
            .unwrap()
            .signal;
        let mut last = first;
        for _ in 0..30 {
            last = learner
                .local_step(&engine, &mut params, &x, &y, &hyper)
                .unwrap()
                .signal;
        }
        assert!(last < first, "NLL did not fall: {first} -> {last}");
        let f1 = learner.evaluate(&engine, &params, &x, &y).unwrap();
        assert!(f1 > 0.9, "F1 {f1} on well-separated blobs");
    }

    #[test]
    fn variances_adapt_toward_batch_scatter() {
        let learner = GmmLearner { k: 2, d: 4 };
        let engine = NativeEngine::default();
        let mut rng = Rng::new(7);
        // Two blobs with very different scatter.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..200 {
            let (c, center, sigma) = if i % 2 == 0 {
                (0, -8.0, 0.2f64)
            } else {
                (1, 8.0, 2.0)
            };
            for _ in 0..4 {
                x.push((center + rng.normal() * sigma) as f32);
            }
            y.push(c);
        }
        let ds = Dataset::new(x.clone(), y.clone(), 4);
        let mut params = learner.init_params(&ds, &mut rng);
        let hyper = Hyper {
            lr: 0.8,
            reg: 0.0,
            lr_decay: 0.0,
        };
        for _ in 0..40 {
            learner
                .local_step(&engine, &mut params, &x, &y, &hyper)
                .unwrap();
        }
        let logvar = &params[learner.means_len()..];
        // Components must end with distinctly different variances, ordered
        // by their blob's scatter (component order is recovered by the
        // means' signs).
        let means0 = params[0];
        let (tight, wide) = if means0 < 0.0 {
            (logvar[0], logvar[1])
        } else {
            (logvar[1], logvar[0])
        };
        assert!(
            tight < wide,
            "tight blob logvar {tight} should be below wide blob {wide}"
        );
    }

    #[test]
    fn empty_component_keeps_parameters() {
        let learner = GmmLearner { k: 2, d: 2 };
        let engine = NativeEngine::default();
        // All points near the origin: the far component stays unassigned.
        let x = vec![0.1f32, -0.1, 0.05, 0.0, -0.02, 0.03];
        let y = vec![0, 0, 0];
        let mut params = vec![0.0, 0.0, 100.0, 100.0, 0.0, 0.0];
        let before_far = [params[2], params[3], params[5]];
        let hyper = Hyper {
            lr: 0.9,
            reg: 0.0,
            lr_decay: 0.0,
        };
        learner
            .local_step(&engine, &mut params, &x, &y, &hyper)
            .unwrap();
        assert_eq!([params[2], params[3], params[5]], before_far);
    }
}
