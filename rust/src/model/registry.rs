//! The task registry: name → [`Learner`] factories, and the [`TaskSpec`]
//! wire type the rest of the system carries instead of a task enum.
//!
//! Grammar (single-sourced in `docs/GRAMMAR.md`):
//!
//! ```text
//! task := NAME ( ':' KEY '=' N )*
//! ```
//!
//! e.g. `svm`, `kmeans:k=5`, `logreg:d=59:c=8`, `gmm:k=3`. `NAME` resolves
//! against the registry; `KEY=N` pairs are integer parameters each factory
//! interprets (unknown keys are typed errors, never silently dropped).
//! The JSON wire format keeps accepting the legacy `"svm"` / `"kmeans"`
//! spellings unchanged (`"k-means"` stays an accepted alias).
//!
//! The registry ships four tasks (`svm`, `kmeans`, `logreg`, `gmm`) and is
//! open: [`register`] adds a new task at runtime, after which its spec
//! works everywhere a task name does — `--task`, the JSON wire format,
//! suites, the fleet simulator. `logreg` and `gmm` are themselves
//! registered through the same factory type an external caller would use.

use std::collections::BTreeMap;
use std::sync::{OnceLock, RwLock};

use anyhow::{anyhow, Result};

use crate::model::learner::Learner;

/// Integer parameters of a task spec (`k=3`, `d=59`, …). Factories take
/// what they understand; [`TaskParams::finish`] rejects leftovers so a
/// typo like `kmeans:q=3` is an error, not a silent default.
pub struct TaskParams {
    pairs: BTreeMap<String, usize>,
}

impl TaskParams {
    fn parse(segments: &[&str]) -> Result<TaskParams> {
        let mut pairs = BTreeMap::new();
        for seg in segments {
            let (key, val) = seg
                .split_once('=')
                .ok_or_else(|| anyhow!("task parameter '{seg}' is not KEY=N"))?;
            let val: usize = val
                .parse()
                .map_err(|_| anyhow!("task parameter '{seg}': '{val}' is not an integer"))?;
            if pairs.insert(key.to_string(), val).is_some() {
                return Err(anyhow!("task parameter '{key}' given twice"));
            }
        }
        Ok(TaskParams { pairs })
    }

    /// Take an integer parameter, falling back to `default` when absent.
    pub fn take(&mut self, key: &str, default: usize) -> usize {
        self.pairs.remove(key).unwrap_or(default)
    }

    /// Error on parameters the factory did not consume.
    pub fn finish(&self, task: &str) -> Result<()> {
        if let Some(key) = self.pairs.keys().next() {
            return Err(anyhow!(
                "task '{task}' does not take a parameter '{key}'"
            ));
        }
        Ok(())
    }
}

/// One registered task: a name plus a factory from spec parameters to a
/// learner. Plain `fn` pointers keep the registry `Send + Sync` without
/// imposing bounds on learners themselves.
pub struct TaskFactory {
    /// Registry name (the spec head, e.g. `"kmeans"`).
    pub name: &'static str,
    /// One-line description for `--help` and diagnostics.
    pub about: &'static str,
    /// Build a learner from the spec's `KEY=N` parameters.
    pub build: fn(&mut TaskParams) -> Result<Box<dyn Learner>>,
}

fn registry() -> &'static RwLock<Vec<TaskFactory>> {
    static REGISTRY: OnceLock<RwLock<Vec<TaskFactory>>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        RwLock::new(vec![
            crate::model::svm::factory(),
            crate::model::kmeans::factory(),
            // The two openness proofs ride the same public factory type an
            // out-of-tree task would use.
            crate::model::logreg::factory(),
            crate::model::gmm::factory(),
        ])
    })
}

/// Register a new task. Errors when the name collides with an existing
/// registration (names are the spec heads and the fused-kernel keys, so
/// they must stay unique).
pub fn register(factory: TaskFactory) -> Result<()> {
    let mut reg = registry().write().unwrap();
    if reg.iter().any(|f| f.name == factory.name) {
        return Err(anyhow!("task '{}' is already registered", factory.name));
    }
    reg.push(factory);
    Ok(())
}

/// Every registered task as `(name, about)`, in registration order.
pub fn registered_tasks() -> Vec<(&'static str, &'static str)> {
    registry()
        .read()
        .unwrap()
        .iter()
        .map(|f| (f.name, f.about))
        .collect()
}

/// Resolve a task spec string into a learner.
pub fn resolve(spec: &str) -> Result<Box<dyn Learner>> {
    let spec = spec.to_ascii_lowercase();
    let mut segments = spec.split(':');
    let head = segments.next().unwrap_or("");
    // Legacy wire alias kept from the enum era.
    let head = if head == "k-means" { "kmeans" } else { head };
    let params: Vec<&str> = segments.collect();
    let reg = registry().read().unwrap();
    let factory = reg
        .iter()
        .find(|f| f.name == head)
        .ok_or_else(|| {
            let known: Vec<&str> = reg.iter().map(|f| f.name).collect();
            anyhow!(
                "unknown task '{head}' (registered: {}; grammar: NAME[:KEY=N]*)",
                known.join(", ")
            )
        })?;
    let mut p = TaskParams::parse(&params)?;
    let learner = (factory.build)(&mut p)?;
    p.finish(head)?;
    Ok(learner)
}

/// A validated task spec — the wire/config representation of a learner.
///
/// Holds the canonical spec string (`learner.spec()` of the resolved
/// learner, so explicitly-spelled default parameters collapse:
/// `kmeans:k=3` canonicalizes to `kmeans`). Cheap to clone and `Send`, so
/// configs cross worker threads freely; the learner itself is
/// materialized per run via [`TaskSpec::learner`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskSpec {
    spec: String,
}

impl TaskSpec {
    /// Parse and validate a task spec against the registry, canonicalizing
    /// the parameter spelling. This is the wire entry point: the JSON
    /// format and `--task` both come through here.
    pub fn parse(s: &str) -> Result<TaskSpec> {
        let learner = resolve(s)?;
        Ok(TaskSpec {
            spec: learner.spec(),
        })
    }

    /// The default SVM task (the paper's supervised scenario).
    pub fn svm() -> TaskSpec {
        TaskSpec {
            spec: "svm".to_string(),
        }
    }

    /// The default K-means task (the paper's unsupervised scenario).
    pub fn kmeans() -> TaskSpec {
        TaskSpec {
            spec: "kmeans".to_string(),
        }
    }

    /// The logistic-regression task (plugin proof, supervised).
    pub fn logreg() -> TaskSpec {
        TaskSpec {
            spec: "logreg".to_string(),
        }
    }

    /// The spherical-GMM task (plugin proof, unsupervised).
    pub fn gmm() -> TaskSpec {
        TaskSpec {
            spec: "gmm".to_string(),
        }
    }

    /// The canonical spec string (what the JSON wire format carries).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// The task's registry name (the spec head).
    pub fn name(&self) -> &str {
        self.spec.split(':').next().unwrap_or(&self.spec)
    }

    /// Materialize the learner. Infallible: a `TaskSpec` only exists via
    /// [`parse`](TaskSpec::parse) or the builtin constructors, and the
    /// registry is append-only.
    pub fn learner(&self) -> Box<dyn Learner> {
        resolve(&self.spec).expect("TaskSpec was validated at construction")
    }
}

impl Default for TaskSpec {
    fn default() -> Self {
        TaskSpec::svm()
    }
}

impl std::fmt::Display for TaskSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_tasks_resolve() {
        for name in ["svm", "kmeans", "logreg", "gmm"] {
            let learner = resolve(name).unwrap();
            assert_eq!(learner.name(), name);
            assert!(learner.param_len() > 0);
        }
    }

    #[test]
    fn legacy_wire_spellings_still_parse() {
        assert_eq!(TaskSpec::parse("SVM").unwrap().name(), "svm");
        assert_eq!(TaskSpec::parse("k-means").unwrap().name(), "kmeans");
        assert_eq!(TaskSpec::parse("kmeans").unwrap(), TaskSpec::kmeans());
    }

    #[test]
    fn parameterized_specs_canonicalize_and_roundtrip() {
        // Non-default parameters survive...
        let spec = TaskSpec::parse("kmeans:k=5").unwrap();
        assert_eq!(spec.spec(), "kmeans:k=5");
        assert_eq!(TaskSpec::parse(spec.spec()).unwrap(), spec);
        // ...explicit defaults collapse to the bare name...
        assert_eq!(TaskSpec::parse("kmeans:k=3").unwrap(), TaskSpec::kmeans());
        // ...and multi-parameter specs keep every non-default.
        let lr = TaskSpec::parse("logreg:d=20:c=4").unwrap();
        assert_eq!(lr.spec(), "logreg:d=20:c=4");
        let learner = lr.learner();
        assert_eq!(learner.param_len(), 20 * 4 + 4);
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        assert!(TaskSpec::parse("mlp").is_err());
        assert!(TaskSpec::parse("kmeans:k").is_err());
        assert!(TaskSpec::parse("kmeans:k=x").is_err());
        assert!(TaskSpec::parse("kmeans:q=3").is_err(), "unknown key accepted");
        assert!(TaskSpec::parse("kmeans:k=3:k=4").is_err(), "dup key accepted");
        let err = TaskSpec::parse("warp").unwrap_err().to_string();
        assert!(err.contains("registered:"), "{err}");
    }

    #[test]
    fn unknown_task_error_lists_registry() {
        let err = resolve("nope").unwrap_err().to_string();
        for name in ["svm", "kmeans", "logreg", "gmm"] {
            assert!(err.contains(name), "{err}");
        }
    }

    #[test]
    fn duplicate_registration_rejected() {
        let err = register(TaskFactory {
            name: "svm",
            about: "imposter",
            build: |_| Err(anyhow!("never")),
        });
        assert!(err.is_err());
    }

    #[test]
    fn registered_tasks_lists_builtins_in_order() {
        let names: Vec<&str> = registered_tasks().iter().map(|(n, _)| *n).collect();
        assert!(names.starts_with(&["svm", "kmeans", "logreg", "gmm"]));
    }
}
