//! The object-safe [`Learner`] plugin API — the open task layer.
//!
//! A learner is ONE value that owns everything task-specific the system
//! ever needs: its parameter layout and initialization, its local
//! iteration and evaluation metric, its aggregation rule, its synthetic
//! data generator and its default deployment shapes. Every other layer —
//! the edge round loop, the coordinator's aggregation and utility
//! metering, the suites, the figure harnesses, the CLI and the fleet
//! simulator — is written against `Box<dyn Learner>` and never matches on
//! a task enum. Adding a workload is one `impl Learner` plus one
//! [`register`](crate::model::registry::register) call (see
//! `docs/ARCHITECTURE.md` § "The task layer"); `model/logreg.rs` and
//! `model/gmm.rs` are in-tree proofs written purely against this API.
//!
//! Learners reach compute through two doors of
//! [`ComputeEngine`](crate::engine::ComputeEngine):
//!
//! * the task-agnostic primitive ops
//!   ([`EngineOps`](crate::engine::EngineOps): gemm/axpy/argmin-distance/
//!   scatter-reduce), implemented once and available on every backend —
//!   the portable path every learner must provide;
//! * optional fused AOT kernels
//!   ([`run_kernel`](crate::engine::ComputeEngine::run_kernel)), keyed by
//!   `"{learner}_{step|eval}"` in the PJRT artifact manifest — an
//!   accelerator fast path a learner MAY use when
//!   [`has_kernel`](crate::engine::ComputeEngine::has_kernel) says the
//!   backend ships one.

use anyhow::Result;

use crate::config::PartitionKind;
use crate::coordinator::aggregate;
use crate::data::Dataset;
use crate::edge::Hyper;
use crate::engine::ComputeEngine;
use crate::util::rng::Rng;

/// Output of one local iteration.
#[derive(Clone, Copy, Debug)]
pub struct StepOut {
    /// Mean training signal of the batch (hinge loss, inertia, NLL, …) —
    /// diagnostics only, never the bandit reward.
    pub signal: f64,
}

/// A pluggable learning task. Object-safe; the system only ever holds
/// `Box<dyn Learner>`.
///
/// The contract every implementation must keep:
///
/// * `local_step` updates `params` in place and must be deterministic in
///   its inputs (all randomness comes from the batch the caller drew);
/// * `evaluate` returns the task's headline metric in `[0, 1]` (the
///   utility meter and the figure tables assume a unit range);
/// * `aggregate` (default: shard-weighted parameter averaging) must
///   return a vector of `param_len()` — it is the synchronous barrier's
///   merge rule. For mean-style parameter layouts (centers, means) the
///   shard-size-weighted average matches the sufficient-statistics merge
///   exactly when assignments are shard-proportional, and approximates
///   it otherwise — override the hook when a task needs the exact
///   statistic (e.g. count-weighted or variance-aware merging);
/// * `synth` must consume the RNG identically for a given `(n, d, …)` so
///   fixed-seed runs reproduce.
pub trait Learner {
    /// Registry name (`"svm"`, `"kmeans"`, `"logreg"`, `"gmm"`, …) — also
    /// the key prefix of the backend's fused kernels.
    fn name(&self) -> &'static str;

    /// Canonical parameterized spec, round-trippable through
    /// [`TaskSpec::parse`](crate::model::TaskSpec::parse) (e.g.
    /// `kmeans:k=5`; bare `name` when every parameter is the default).
    /// This is what the JSON wire format carries.
    fn spec(&self) -> String;

    /// Whether the task consumes labels (drives the paper regime's
    /// default sharding: label-skew for supervised tasks, IID otherwise).
    fn supervised(&self) -> bool;

    /// Display name of the evaluation metric (`"accuracy"`, `"F1"`, …).
    fn metric_name(&self) -> &'static str;

    /// Flat parameter count of the model.
    fn param_len(&self) -> usize;

    /// Local-iteration batch size (rows per `local_step`).
    fn batch(&self) -> usize {
        64
    }

    /// Eval batch size (rows in the Cloud's held-out test buffer).
    fn eval_batch(&self) -> usize {
        512
    }

    /// Generate the training corpus (`n` pre-shuffled rows at the given
    /// generator difficulty).
    fn synth(&self, n: usize, separation: f64, rng: &mut Rng) -> Dataset;

    /// The global model at t=0 (paper: "set the global model randomly").
    /// May inspect the training data for data-dependent seeding (e.g.
    /// k-means++ over a subsample).
    fn init_params(&self, train: &Dataset, rng: &mut Rng) -> Vec<f32>;

    /// One local iteration on a batch; `params` updated in place.
    fn local_step(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<StepOut>;

    /// One local iteration for each of `params.len()` edges in a single
    /// call — the batch-of-edges door that lets one engine dispatch
    /// advance a whole cohort. `x`/`y` stack the edges' batches in edge
    /// order (equal-size chunks, `params.len()` of each); entry `g` of
    /// the result is edge `g`'s [`StepOut`].
    ///
    /// The determinism contract: the result — every updated `params[g]`
    /// and every signal — must be bit-identical to `params.len()`
    /// sequential [`local_step`](Learner::local_step) calls on the same
    /// per-edge chunks. The default is exactly that loop; overrides
    /// (svm/logreg stack a tall grouped gemm, kmeans/gmm fuse grouped
    /// assign + scatter) keep the contract by preserving every
    /// within-edge accumulation order, and are asserted bit-equal in
    /// rust/tests/batch_parity.rs.
    fn local_step_batch(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [&mut [f32]],
        x: &[f32],
        y: &[i32],
        hyper: &Hyper,
    ) -> Result<Vec<StepOut>> {
        let e = params.len();
        if e == 0 {
            return Ok(Vec::new());
        }
        let (px, py) = (x.len() / e, y.len() / e);
        let mut outs = Vec::with_capacity(e);
        for (g, p) in params.iter_mut().enumerate() {
            outs.push(self.local_step(
                engine,
                p,
                &x[g * px..(g + 1) * px],
                &y[g * py..(g + 1) * py],
                hyper,
            )?);
        }
        Ok(outs)
    }

    /// Headline test metric of `params` on an eval buffer, in `[0, 1]`.
    fn evaluate(
        &self,
        engine: &dyn ComputeEngine,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<f64>;

    /// The synchronous barrier's merge rule: fold the cohort's local
    /// parameter vectors (with their aggregation weights) into the next
    /// global vector. Default: normalized weighted averaging — correct
    /// for SGD-family tasks and a close approximation for mean-style
    /// layouts (exact when assignments are shard-proportional); override
    /// for tasks needing an exact sufficient-statistics merge.
    fn aggregate(&self, locals: &[(&[f32], f64)]) -> Vec<f32> {
        aggregate::weighted_average_params(locals)
    }

    /// The paper-figure sharding regime for this task (see
    /// [`RunConfig::with_paper_utility`](crate::config::RunConfig::with_paper_utility)).
    fn paper_partition(&self) -> PartitionKind {
        if self.supervised() {
            PartitionKind::LabelSkew { alpha: 0.5 }
        } else {
            PartitionKind::Iid
        }
    }

    /// Clone into a fresh box (object-safe `Clone`).
    fn clone_box(&self) -> Box<dyn Learner>;
}

impl Clone for Box<dyn Learner> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TaskSpec;

    #[test]
    fn default_paper_partition_follows_supervision() {
        let svm = TaskSpec::svm().learner();
        assert!(svm.supervised());
        assert!(matches!(
            svm.paper_partition(),
            PartitionKind::LabelSkew { .. }
        ));
        let km = TaskSpec::kmeans().learner();
        assert!(!km.supervised());
        assert_eq!(km.paper_partition(), PartitionKind::Iid);
    }

    #[test]
    fn default_aggregate_is_weighted_average() {
        let learner = TaskSpec::kmeans().learner();
        let a = vec![0.0f32; learner.param_len()];
        let mut b = vec![0.0f32; learner.param_len()];
        b[0] = 2.0;
        let merged = learner.aggregate(&[(a.as_slice(), 1.0), (b.as_slice(), 1.0)]);
        assert_eq!(merged.len(), learner.param_len());
        assert!((merged[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn boxed_learner_clones() {
        let learner: Box<dyn Learner> = TaskSpec::svm().learner();
        let twin = learner.clone();
        assert_eq!(twin.name(), "svm");
        assert_eq!(twin.param_len(), learner.param_len());
    }
}
