//! Model state shared between edges and the Cloud.
//!
//! Both use cases carry their parameters as a flat `Vec<f32>` so the
//! coordinator's aggregation (weighted averaging) is model-agnostic:
//! * SVM: `[w (d*c, row-major), b (c)]`
//! * K-means: `[centers (k*d, row-major)]`

pub mod kmeans;
pub mod svm;

/// Which learning task the system is training (paper §V-A: SVM supervised,
/// K-means unsupervised).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Multi-class linear SVM (wafer-map-like classification).
    Svm,
    /// Mini-batch K-means (traffic-stream-like clustering).
    Kmeans,
}

impl Task {
    /// Canonical display/wire name.
    pub fn name(self) -> &'static str {
        match self {
            Task::Svm => "svm",
            Task::Kmeans => "kmeans",
        }
    }

    /// Parse a task name (`svm | kmeans`).
    pub fn parse(s: &str) -> Option<Task> {
        match s.to_ascii_lowercase().as_str() {
            "svm" => Some(Task::Svm),
            "kmeans" | "k-means" => Some(Task::Kmeans),
            _ => None,
        }
    }
}

/// Flat parameter vector + the task tag. The layout contract with the
/// engines is documented above.
#[derive(Clone, Debug)]
pub struct ModelState {
    /// Which task the parameters belong to.
    pub task: Task,
    /// Flat parameter buffer (layout per task, see the module docs).
    pub params: Vec<f32>,
}

impl ModelState {
    /// An all-zeros model of the given task and length.
    pub fn zeros(task: Task, len: usize) -> Self {
        ModelState {
            task,
            params: vec![0.0; len],
        }
    }

    /// Flat parameter count.
    pub fn len(&self) -> usize {
        self.params.len()
    }

    /// Whether the model has no parameters.
    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Euclidean distance to another state (the paper's K-means learning
    /// utility is the negative of this between consecutive slots).
    pub fn l2_distance(&self, other: &ModelState) -> f64 {
        assert_eq!(self.params.len(), other.params.len());
        self.params
            .iter()
            .zip(&other.params)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// In-place: self = self * (1 - w) + other * w.
    pub fn lerp_from(&mut self, other: &ModelState, w: f64) {
        assert_eq!(self.params.len(), other.params.len());
        let w = w as f32;
        for (a, b) in self.params.iter_mut().zip(&other.params) {
            *a = *a * (1.0 - w) + *b * w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_distance_basic() {
        let a = ModelState {
            task: Task::Svm,
            params: vec![0.0, 3.0],
        };
        let b = ModelState {
            task: Task::Svm,
            params: vec![4.0, 0.0],
        };
        assert!((a.l2_distance(&b) - 5.0).abs() < 1e-9);
        assert_eq!(a.l2_distance(&a), 0.0);
    }

    #[test]
    fn lerp_midpoint() {
        let mut a = ModelState {
            task: Task::Kmeans,
            params: vec![0.0, 2.0],
        };
        let b = ModelState {
            task: Task::Kmeans,
            params: vec![2.0, 0.0],
        };
        a.lerp_from(&b, 0.5);
        assert_eq!(a.params, vec![1.0, 1.0]);
    }

    #[test]
    fn task_parse() {
        assert_eq!(Task::parse("SVM"), Some(Task::Svm));
        assert_eq!(Task::parse("k-means"), Some(Task::Kmeans));
        assert_eq!(Task::parse("mlp"), None);
    }
}
