//! The task layer: model state shared between edges and the Cloud, and
//! the open [`Learner`] plugin API that replaced the closed SVM/K-means
//! task enum.
//!
//! Every task carries its parameters as a flat `Vec<f32>` so the
//! coordinator's merges stay model-agnostic; everything else that is
//! task-specific — parameter layout and init, the local iteration, the
//! evaluation metric, the aggregation rule, the synthetic data generator
//! and the default shapes — lives behind the object-safe [`Learner`]
//! trait, resolved by name through the [`registry`] (wire type:
//! [`TaskSpec`], grammar `NAME[:KEY=N]*`, e.g. `kmeans:k=5`).
//!
//! In-tree learners (flat parameter layouts):
//!
//! * [`svm`] — multi-class linear SVM, `[w (d*c, row-major), b (c)]`
//!   (wafer-map-like classification, paper §V-A supervised);
//! * [`kmeans`] — mini-batch K-means, `[centers (k*d, row-major)]`
//!   (traffic-stream-like clustering, paper §V-A unsupervised);
//! * [`logreg`] — multinomial logistic regression, `[w (d*c), b (c)]`
//!   (plugin proof, written purely against the public API);
//! * [`gmm`] — spherical GMM via hard EM, `[means (k*d), logvar (k)]`
//!   (plugin proof, unsupervised).

pub mod gmm;
pub mod kmeans;
pub mod learner;
pub mod logreg;
pub mod registry;
pub mod svm;

pub use learner::{Learner, StepOut};
pub use registry::{register, registered_tasks, TaskFactory, TaskParams, TaskSpec};

/// Flat parameter vector. The layout contract is owned by the task's
/// [`Learner`] (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelState {
    /// Flat parameter buffer (layout per task, see the module docs).
    pub params: Vec<f32>,
}

impl ModelState {
    /// A model over the given flat parameters.
    pub fn new(params: Vec<f32>) -> Self {
        ModelState { params }
    }

    /// An all-zeros model of the given length.
    pub fn zeros(len: usize) -> Self {
        ModelState {
            params: vec![0.0; len],
        }
    }

    /// Flat parameter count.
    pub fn len(&self) -> usize {
        self.params.len()
    }

    /// Whether the model has no parameters.
    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Euclidean distance to another state (the paper's K-means learning
    /// utility is the negative of this between consecutive slots).
    pub fn l2_distance(&self, other: &ModelState) -> f64 {
        assert_eq!(self.params.len(), other.params.len());
        self.params
            .iter()
            .zip(&other.params)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// In-place: self = self * (1 - w) + other * w.
    pub fn lerp_from(&mut self, other: &ModelState, w: f64) {
        assert_eq!(self.params.len(), other.params.len());
        let w = w as f32;
        for (a, b) in self.params.iter_mut().zip(&other.params) {
            *a = *a * (1.0 - w) + *b * w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_distance_basic() {
        let a = ModelState::new(vec![0.0, 3.0]);
        let b = ModelState::new(vec![4.0, 0.0]);
        assert!((a.l2_distance(&b) - 5.0).abs() < 1e-9);
        assert_eq!(a.l2_distance(&a), 0.0);
    }

    #[test]
    fn lerp_midpoint() {
        let mut a = ModelState::new(vec![0.0, 2.0]);
        let b = ModelState::new(vec![2.0, 0.0]);
        a.lerp_from(&b, 0.5);
        assert_eq!(a.params, vec![1.0, 1.0]);
    }

    #[test]
    fn zeros_and_len() {
        let z = ModelState::zeros(4);
        assert_eq!(z.len(), 4);
        assert!(!z.is_empty());
        assert!(z.params.iter().all(|&p| p == 0.0));
    }
}
