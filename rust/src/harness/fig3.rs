//! Figure 3 — "Model Accuracy vs. Heterogeneity" (paper §V-B.1), as a
//! declarative [`ExperimentSuite`] grid.
//!
//! Testbed regime: 3 edge servers, fixed per-edge budget 5000 ms, sweep the
//! heterogeneity ratio H; report K-means F1 (a) and SVM accuracy (b) for
//! OL4EL-sync, OL4EL-async, AC-sync and Fixed-I. The paper's claims this
//! bench regenerates:
//!   * accuracy of ALL algorithms falls as H grows;
//!   * OL4EL variants dominate both baselines;
//!   * OL4EL-sync leads at low H (≤5), OL4EL-async takes over at high H;
//!   * OL4EL-async peaks at ~12% over the baselines.

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::{find_outcome, ExperimentSuite, SuiteOutcome};
use crate::harness::{paper_strategies, SweepOpts};
use crate::model::{Learner as _, TaskSpec};
use crate::strategy::StrategySpec;
use crate::util::table::{f, Table};

/// Heterogeneity ratios swept (H axis).
pub fn hetero_grid(quick: bool) -> Vec<f64> {
    if quick {
        vec![1.0, 3.0, 6.0, 10.0]
    } else {
        vec![1.0, 2.0, 3.0, 5.0, 6.0, 8.0, 10.0]
    }
}

/// The config for one Fig. 3 cell.
pub fn cell_config(task: &TaskSpec, strategy: &StrategySpec, h: f64, opts: &SweepOpts) -> RunConfig {
    RunConfig {
        task: task.clone(),
        strategy: strategy.clone(),
        n_edges: 3,
        hetero: h,
        budget: 5000.0,
        data_n: opts.data_n(),
        ..Default::default()
    }
    .with_paper_utility()
}

/// The Fig. 3 grid: tasks × strategies × heterogeneity, every cell built
/// by [`cell_config`].
pub fn suite(opts: &SweepOpts) -> ExperimentSuite {
    let o = opts.clone();
    let strategies = paper_strategies();
    ExperimentSuite::new(
        "fig3",
        cell_config(&TaskSpec::kmeans(), &strategies[0], 1.0, opts),
    )
    .tasks([TaskSpec::kmeans(), TaskSpec::svm()])
    .strategies(strategies)
    .heteros(hetero_grid(opts.quick))
    .seeds(opts.seed_list())
    .configure(move |cfg| {
        *cfg = cell_config(&cfg.task.clone(), &cfg.strategy.clone(), cfg.hetero, &o)
    })
}

fn cell<'a>(
    outs: &'a [SuiteOutcome],
    task: &TaskSpec,
    strategy: &StrategySpec,
    h: f64,
) -> Result<&'a SuiteOutcome> {
    find_outcome(outs, task, strategy, 3, h)
        .ok_or_else(|| anyhow!("fig3: missing cell {task}/{strategy}/H={h}"))
}

/// Run the full sweep; returns one table per task plus the headline-gap
/// summary row (the paper's "12% enhancement").
pub fn run(opts: &SweepOpts) -> Result<Vec<Table>> {
    let outcomes = suite(opts).run(opts.engine, &opts.artifacts)?;
    let grid = hetero_grid(opts.quick);
    let mut tables = Vec::new();
    let mut best_gap = (0.0f64, 0.0f64, TaskSpec::svm()); // (gap, H, task)

    for task in [TaskSpec::kmeans(), TaskSpec::svm()] {
        let metric_name = task.learner().metric_name();
        let mut t = Table::new(
            format!(
                "Fig 3{}: {} {} vs heterogeneity (budget 5000ms, 3 edges)",
                if task.name() == "kmeans" { "a" } else { "b" },
                task.name(),
                metric_name
            ),
            &["H", "ol4el-sync", "ol4el-async", "ac-sync", "fixed-i", "async-vs-best-baseline"],
        );
        for &h in &grid {
            let mut row = vec![f(h, 0)];
            let mut cells = Vec::new();
            for strategy in paper_strategies() {
                cells.push(cell(&outcomes, &task, &strategy, h)?.agg.metric.mean());
            }
            let baseline_best = cells[2].max(cells[3]);
            let gap = cells[1] - baseline_best;
            if gap > best_gap.0 {
                best_gap = (gap, h, task.clone());
            }
            for c in &cells {
                row.push(f(*c, 4));
            }
            row.push(format!("{:+.1}%", gap * 100.0));
            t.row(row);
        }
        tables.push(t);
    }

    let mut summary = Table::new(
        "Fig 3 summary: peak OL4EL-async enhancement over best baseline (paper: ~12%)",
        &["task", "H", "gap"],
    );
    summary.row(vec![
        best_gap.2.name().to_string(),
        f(best_gap.1, 0),
        format!("{:+.1}%", best_gap.0 * 100.0),
    ]);
    tables.push(summary);
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_sorted_and_starts_homogeneous() {
        for quick in [true, false] {
            let g = hetero_grid(quick);
            assert_eq!(g[0], 1.0);
            assert!(g.windows(2).all(|w| w[1] > w[0]));
        }
    }

    #[test]
    fn cell_config_matches_paper_regime() {
        let cfg = cell_config(&TaskSpec::svm(), &StrategySpec::ac_sync(), 6.0, &SweepOpts::default());
        assert_eq!(cfg.n_edges, 3);
        assert_eq!(cfg.budget, 5000.0);
        assert_eq!(cfg.hetero, 6.0);
    }

    #[test]
    fn suite_grid_matches_cell_config() {
        let opts = SweepOpts::default();
        let cells = suite(&opts).cells();
        assert_eq!(cells.len(), 2 * paper_strategies().len() * hetero_grid(true).len());
        for (spec, cfg) in &cells {
            let expect = cell_config(&spec.task, &spec.strategy, spec.hetero, &opts);
            assert_eq!(cfg.n_edges, expect.n_edges);
            assert_eq!(cfg.budget, expect.budget);
            assert_eq!(cfg.partition, expect.partition);
            assert_eq!(cfg.data_n, expect.data_n);
        }
    }
}
