//! Figure 3 — "Model Accuracy vs. Heterogeneity" (paper §V-B.1).
//!
//! Testbed regime: 3 edge servers, fixed per-edge budget 5000 ms, sweep the
//! heterogeneity ratio H; report K-means F1 (a) and SVM accuracy (b) for
//! OL4EL-sync, OL4EL-async, AC-sync and Fixed-I. The paper's claims this
//! bench regenerates:
//!   * accuracy of ALL algorithms falls as H grows;
//!   * OL4EL variants dominate both baselines;
//!   * OL4EL-sync leads at low H (≤5), OL4EL-async takes over at high H;
//!   * OL4EL-async peaks at ~12% over the baselines.

use anyhow::Result;

use crate::config::{Algo, RunConfig};
use crate::engine::ComputeEngine;
use crate::harness::{run_seeds, SweepOpts};
use crate::model::Task;
use crate::util::table::{f, Table};

pub const ALGOS: [Algo; 4] = [Algo::Ol4elSync, Algo::Ol4elAsync, Algo::AcSync, Algo::FixedI];

pub fn hetero_grid(quick: bool) -> Vec<f64> {
    if quick {
        vec![1.0, 3.0, 6.0, 10.0]
    } else {
        vec![1.0, 2.0, 3.0, 5.0, 6.0, 8.0, 10.0]
    }
}

/// The config for one Fig. 3 cell.
pub fn cell_config(task: Task, algo: Algo, h: f64, opts: &SweepOpts) -> RunConfig {
    RunConfig {
        task,
        algo,
        n_edges: 3,
        hetero: h,
        budget: 5000.0,
        data_n: opts.data_n(),
        ..Default::default()
    }
    .with_paper_utility()
}

/// Run the full sweep; returns one table per task plus the headline-gap
/// summary row (the paper's "12% enhancement").
pub fn run(engine: &dyn ComputeEngine, opts: &SweepOpts) -> Result<Vec<Table>> {
    let seeds = opts.seed_list();
    let grid = hetero_grid(opts.quick);
    let mut tables = Vec::new();
    let mut best_gap = (0.0f64, 0.0f64, Task::Svm); // (gap, H, task)

    for task in [Task::Kmeans, Task::Svm] {
        let metric_name = match task {
            Task::Kmeans => "F1",
            Task::Svm => "accuracy",
        };
        let mut t = Table::new(
            format!("Fig 3{}: {} {} vs heterogeneity (budget 5000ms, 3 edges)",
                if task == Task::Kmeans { "a" } else { "b" },
                task.name(),
                metric_name
            ),
            &["H", "ol4el-sync", "ol4el-async", "ac-sync", "fixed-i", "async-vs-best-baseline"],
        );
        for &h in &grid {
            let mut row = vec![f(h, 0)];
            let mut cells = Vec::new();
            for algo in ALGOS {
                let cfg = cell_config(task, algo, h, opts);
                let agg = run_seeds(&cfg, engine, &seeds)?;
                cells.push(agg.metric.mean());
            }
            let baseline_best = cells[2].max(cells[3]);
            let gap = cells[1] - baseline_best;
            if gap > best_gap.0 {
                best_gap = (gap, h, task);
            }
            for c in &cells {
                row.push(f(*c, 4));
            }
            row.push(format!("{:+.1}%", gap * 100.0));
            t.row(row);
        }
        tables.push(t);
    }

    let mut summary = Table::new(
        "Fig 3 summary: peak OL4EL-async enhancement over best baseline (paper: ~12%)",
        &["task", "H", "gap"],
    );
    summary.row(vec![
        best_gap.2.name().to_string(),
        f(best_gap.1, 0),
        format!("{:+.1}%", best_gap.0 * 100.0),
    ]);
    tables.push(summary);
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_sorted_and_starts_homogeneous() {
        for quick in [true, false] {
            let g = hetero_grid(quick);
            assert_eq!(g[0], 1.0);
            assert!(g.windows(2).all(|w| w[1] > w[0]));
        }
    }

    #[test]
    fn cell_config_matches_paper_regime() {
        let cfg = cell_config(Task::Svm, Algo::AcSync, 6.0, &SweepOpts::default());
        assert_eq!(cfg.n_edges, 3);
        assert_eq!(cfg.budget, 5000.0);
        assert_eq!(cfg.hetero, 6.0);
    }
}
