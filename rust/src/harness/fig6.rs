//! "Figure 6" — a scale figure the paper's 3-edge testbed could not
//! produce: OL4EL's update throughput under fleet size × network
//! conditions × churn, measured with the engine-free [`FleetSim`] over the
//! message-passing transport.
//!
//! The sweep asks the system-scale questions the ROADMAP's heavy-traffic
//! north star cares about: how does the asynchronous protocol's update
//! rate degrade as WAN latency grows heavy-tailed, how much work do drops
//! waste, and what does Poisson churn do to effective fleet capacity —
//! at thousands of edges, in seconds of host time.

use anyhow::Result;

use crate::config::RunConfig;
use crate::harness::SweepOpts;
use crate::net::{ChurnSpec, FleetSim, NetworkSpec};
use crate::strategy::StrategySpec;
use crate::util::stats::Welford;
use crate::util::table::{f, Table};

/// Fleet sizes swept.
pub fn edge_grid(quick: bool) -> Vec<usize> {
    if quick {
        vec![100, 500, 2000]
    } else {
        vec![1000, 5000, 10_000]
    }
}

/// (label, spec) network conditions swept per fleet size.
pub fn network_grid() -> Vec<(&'static str, NetworkSpec)> {
    vec![
        ("ideal", NetworkSpec::ideal()),
        (
            "lan 5ms",
            NetworkSpec::parse("lognormal:5:0.3").expect("static spec"),
        ),
        (
            "wan 20ms+drops",
            NetworkSpec::parse("lognormal:20:0.8,drop:0.02").expect("static spec"),
        ),
    ]
}

/// (label, spec) churn schedules swept per fleet size.
pub fn churn_grid() -> Vec<(&'static str, ChurnSpec)> {
    vec![
        ("static", ChurnSpec::none()),
        (
            "churny",
            ChurnSpec::parse("poisson:0.05,join:0.1,restart:2000").expect("static spec"),
        ),
    ]
}

/// A [`FleetSim`] honoring the sweep's shard override (0 = the default,
/// available parallelism). Any value yields bit-identical results.
fn sim_with_shards(cfg: RunConfig, shards: usize) -> Result<FleetSim> {
    let sim = FleetSim::new(cfg)?;
    Ok(if shards > 0 { sim.shards(shards) } else { sim })
}

/// The base fleet config for one cell.
pub fn cell_config(n: usize, strategy: StrategySpec) -> RunConfig {
    RunConfig {
        strategy,
        n_edges: n,
        hetero: 4.0,
        budget: 3000.0,
        eval_every: 1000,
        data_n: 20_000.max(n + 512),
        ..Default::default()
    }
}

/// Run the sweep; one table of async fleet behavior plus a sync straggler
/// comparison column.
pub fn run(opts: &SweepOpts) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 6: fleet scale x network x churn (engine-free protocol sim, budget 3000ms)",
        &[
            "edges",
            "network",
            "churn",
            "updates",
            "upd/edge",
            "lost msgs",
            "joined",
            "virtual wall s",
            "sync updates",
            "Mevents/s",
        ],
    );
    for n in edge_grid(opts.quick) {
        for (net_label, net) in network_grid() {
            for (churn_label, churn) in churn_grid() {
                let mut updates = Welford::new();
                let mut lost = Welford::new();
                let mut joined = Welford::new();
                let mut wall = Welford::new();
                let mut sync_updates = Welford::new();
                let mut evps = Welford::new();
                for seed in opts.seed_list() {
                    let mut cfg = cell_config(n, StrategySpec::ol4el_async());
                    cfg.network = net.clone();
                    cfg.churn = churn.clone();
                    cfg.seed = seed;
                    let r = sim_with_shards(cfg.clone(), opts.shards)?.run()?;
                    updates.push(r.updates as f64);
                    lost.push(r.messages_lost as f64);
                    joined.push(r.joined as f64);
                    wall.push(r.wall_ms / 1000.0);
                    evps.push(r.events_per_sec());
                    let mut scfg = cfg;
                    scfg.strategy = StrategySpec::ol4el_sync();
                    let rs = sim_with_shards(scfg, opts.shards)?.run()?;
                    sync_updates.push(rs.updates as f64);
                }
                t.row(vec![
                    n.to_string(),
                    net_label.to_string(),
                    churn_label.to_string(),
                    f(updates.mean(), 0),
                    f(updates.mean() / n as f64, 2),
                    f(lost.mean(), 0),
                    f(joined.mean(), 0),
                    f(wall.mean(), 1),
                    f(sync_updates.mean(), 0),
                    f(evps.mean() / 1e6, 2),
                ]);
            }
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_wellformed() {
        assert_eq!(edge_grid(true).len(), 3);
        assert!(edge_grid(false).iter().all(|&n| n >= 1000));
        for (label, n) in network_grid() {
            assert!(n.check().is_ok(), "{label}");
        }
        for (label, c) in churn_grid() {
            assert!(c.check().is_ok(), "{label}");
        }
    }

    #[test]
    fn tiny_sweep_produces_full_grid() {
        // A miniature fig6: every (network x churn) cell at one small
        // fleet size, single seed — the full harness in microcosm.
        let mut rows = 0;
        for (_, net) in network_grid() {
            for (_, churn) in churn_grid() {
                let mut cfg = cell_config(50, StrategySpec::ol4el_async());
                cfg.budget = 800.0;
                cfg.network = net.clone();
                cfg.churn = churn.clone();
                let r = FleetSim::new(cfg).unwrap().run().unwrap();
                assert!(r.updates > 0);
                rows += 1;
            }
        }
        assert_eq!(rows, 6);
    }
}
