//! Figure 4 — "Model Accuracy vs. Edge Resource Consumption" (paper
//! §V-B.2): the long-run trade-off at heterogeneity H = 6, as a declarative
//! [`ExperimentSuite`] grid.
//!
//! For each algorithm, record the (mean consumed resource, metric) trace of
//! a run and resample it onto a common consumption grid so the curves are
//! directly comparable (multi-seed averaged per grid point). Claims this
//! regenerates:
//!   * all curves rise with consumption (the intrinsic trade-off);
//!   * OL4EL curves dominate AC-sync everywhere;
//!   * OL4EL-async ends highest once enough resource is consumed.

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::{self, find_outcome, ExperimentSuite};
use crate::harness::{paper_strategies, SweepOpts};
use crate::model::{Learner as _, TaskSpec};
use crate::strategy::StrategySpec;
use crate::util::stats::Welford;
use crate::util::table::{f, Table};

/// Fixed heterogeneity ratio of the Fig. 4 scenario.
pub const HETERO: f64 = 6.0;

/// The run config of one (task, strategy) cell.
pub fn cell_config(task: &TaskSpec, strategy: &StrategySpec, opts: &SweepOpts) -> RunConfig {
    RunConfig {
        task: task.clone(),
        strategy: strategy.clone(),
        n_edges: 3,
        hetero: HETERO,
        budget: 5000.0,
        data_n: opts.data_n(),
        ..Default::default()
    }
    .with_paper_utility()
}

/// The Fig. 4 grid: tasks × strategies at H = 6.
pub fn suite(opts: &SweepOpts) -> ExperimentSuite {
    let o = opts.clone();
    let strategies = paper_strategies();
    ExperimentSuite::new("fig4", cell_config(&TaskSpec::kmeans(), &strategies[0], opts))
        .tasks([TaskSpec::kmeans(), TaskSpec::svm()])
        .strategies(strategies)
        .seeds(opts.seed_list())
        // Fig. 4 resamples full traces onto the consumption grid, so the
        // per-seed RunResults must be kept.
        .retain_runs(true)
        .configure(move |cfg| *cfg = cell_config(&cfg.task.clone(), &cfg.strategy.clone(), &o))
}

/// Metric of a trace at consumption level `x` (step interpolation — the
/// metric last observed at or below x).
fn metric_at(trace: &[coordinator::TracePoint], x: f64) -> f64 {
    let mut m = trace.first().map(|p| p.metric).unwrap_or(0.0);
    for p in trace {
        if p.mean_spent <= x {
            m = p.metric;
        } else {
            break;
        }
    }
    m
}

/// Evenly spaced consumption checkpoints up to `budget`.
pub fn consumption_grid(budget: f64, points: usize) -> Vec<f64> {
    (1..=points)
        .map(|i| budget * i as f64 / points as f64)
        .collect()
}

/// Run the sweep and render its tables.
pub fn run(opts: &SweepOpts) -> Result<Vec<Table>> {
    let outcomes = suite(opts).run(opts.engine, &opts.artifacts)?;
    let grid = consumption_grid(5000.0, if opts.quick { 8 } else { 16 });
    let mut tables = Vec::new();

    let strategies = paper_strategies();
    for task in [TaskSpec::kmeans(), TaskSpec::svm()] {
        let metric_name = task.learner().metric_name();
        let mut header: Vec<String> = vec!["consumed_ms".into()];
        header.extend(strategies.iter().map(|s| s.label()));
        let mut t = Table::new(
            format!(
                "Fig 4 ({}): {} vs mean edge resource consumption (H=6)",
                task.name(),
                metric_name
            ),
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );

        // curves[strategy][grid_idx] = Welford over seeds
        let mut curves: Vec<Vec<Welford>> =
            vec![vec![Welford::new(); grid.len()]; strategies.len()];
        for (ai, strategy) in strategies.iter().enumerate() {
            let outcome = find_outcome(&outcomes, &task, strategy, 3, HETERO)
                .ok_or_else(|| anyhow!("fig4: missing cell {task}/{strategy}"))?;
            for run in &outcome.runs {
                for (gi, &x) in grid.iter().enumerate() {
                    curves[ai][gi].push(metric_at(&run.trace, x));
                }
            }
        }
        for (gi, &x) in grid.iter().enumerate() {
            let mut row = vec![f(x, 0)];
            for curve in &curves {
                row.push(f(curve[gi].mean(), 4));
            }
            t.row(row);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TracePoint;

    fn tp(spent: f64, metric: f64) -> TracePoint {
        TracePoint {
            wall_ms: spent,
            mean_spent: spent,
            updates: 0,
            metric,
        }
    }

    #[test]
    fn metric_at_is_step_interpolation() {
        let trace = vec![tp(0.0, 0.1), tp(100.0, 0.5), tp(200.0, 0.8)];
        assert_eq!(metric_at(&trace, 50.0), 0.1);
        assert_eq!(metric_at(&trace, 100.0), 0.5);
        assert_eq!(metric_at(&trace, 150.0), 0.5);
        assert_eq!(metric_at(&trace, 1000.0), 0.8);
    }

    #[test]
    fn grid_spans_budget() {
        let g = consumption_grid(5000.0, 10);
        assert_eq!(g.len(), 10);
        assert_eq!(*g.last().unwrap(), 5000.0);
        assert!(g[0] > 0.0);
    }

    #[test]
    fn suite_covers_tasks_and_strategies() {
        let cells = suite(&SweepOpts::default()).cells();
        assert_eq!(cells.len(), 2 * paper_strategies().len());
        assert!(cells.iter().all(|(s, c)| s.hetero == HETERO && c.budget == 5000.0));
    }
}
