//! Experiment harness: turns `RunConfig`s into the tables/series the paper
//! reports. One submodule per paper figure (Fig. 3, 4, 5); each is driven
//! both by `cargo bench --bench figN` and by the `ol4el figN` CLI.

pub mod fig3;
pub mod fig4;
pub mod fig5;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::{self, RunResult};
use crate::engine::native::NativeEngine;
use crate::engine::pjrt::PjrtEngine;
use crate::engine::ComputeEngine;
use crate::util::stats::Welford;

/// Which compute backend the harness runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure Rust (fast, shape-flexible) — the simulator default.
    Native,
    /// AOT HLO on PJRT — the full three-layer path (testbed default).
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
        }
    }
}

/// Instantiate an engine. For `Pjrt` the artifact dir must exist
/// (`make artifacts`).
pub fn build_engine(kind: EngineKind, artifacts_dir: &str) -> Result<Box<dyn ComputeEngine>> {
    match kind {
        EngineKind::Native => Ok(Box::new(NativeEngine::default())),
        EngineKind::Pjrt => {
            let eng = PjrtEngine::open(artifacts_dir)
                .map_err(|e| anyhow!("opening artifacts at '{artifacts_dir}': {e}"))?;
            eng.warmup()?;
            Ok(Box::new(eng))
        }
    }
}

/// Multi-seed aggregate of a config.
#[derive(Clone, Debug)]
pub struct Aggregate {
    pub metric: Welford,
    pub updates: Welford,
    pub auc: Welford,
    pub sample: Option<RunResult>,
}

impl Aggregate {
    pub fn empty() -> Self {
        Aggregate {
            metric: Welford::new(),
            updates: Welford::new(),
            auc: Welford::new(),
            sample: None,
        }
    }
}

/// Run `cfg` across `seeds` and aggregate the headline numbers.
pub fn run_seeds(
    cfg: &RunConfig,
    engine: &dyn ComputeEngine,
    seeds: &[u64],
) -> Result<Aggregate> {
    assert!(!seeds.is_empty());
    let mut agg = Aggregate::empty();
    for &seed in seeds {
        let mut c = cfg.clone();
        c.seed = seed;
        let r = coordinator::run(&c, engine)?;
        agg.metric.push(r.final_metric);
        agg.updates.push(r.total_updates as f64);
        agg.auc.push(r.tradeoff_auc());
        if agg.sample.is_none() {
            agg.sample = Some(r);
        }
    }
    Ok(agg)
}

/// Shared sizing knobs for the figure benches: `quick` keeps `cargo bench`
/// wall-time reasonable on one core; `full` mirrors the paper's sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepOpts {
    pub quick: bool,
    pub seeds: u64,
    pub engine: EngineKind,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts {
            quick: true,
            seeds: 2,
            engine: EngineKind::Native,
        }
    }
}

impl SweepOpts {
    pub fn seed_list(&self) -> Vec<u64> {
        (0..self.seeds.max(1)).map(|i| 42 + i).collect()
    }

    /// Training-set size scaled for bench speed (batch shape is fixed, so
    /// a smaller corpus only changes shard diversity, not step cost).
    pub fn data_n(&self) -> usize {
        if self.quick {
            6_000
        } else {
            20_000
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("PJRT"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("tpu"), None);
    }

    #[test]
    fn run_seeds_aggregates() {
        let engine = NativeEngine::default();
        let cfg = RunConfig {
            data_n: 3000,
            budget: 600.0,
            ..Default::default()
        };
        let agg = run_seeds(&cfg, &engine, &[1, 2]).unwrap();
        assert_eq!(agg.metric.count(), 2);
        assert!(agg.sample.is_some());
        assert!(agg.metric.mean() > 0.0);
    }

    #[test]
    fn sweep_opts_sizes() {
        let q = SweepOpts::default();
        assert_eq!(q.data_n(), 6000);
        assert_eq!(q.seed_list(), vec![42, 43]);
        let f = SweepOpts {
            quick: false,
            seeds: 3,
            engine: EngineKind::Native,
        };
        assert_eq!(f.data_n(), 20000);
        assert_eq!(f.seed_list().len(), 3);
    }
}
