//! Experiment harness: turns declarative grids into the tables/series the
//! paper reports. One submodule per paper figure (Fig. 3, 4, 5); each is a
//! grid spec over [`ExperimentSuite`](crate::coordinator::ExperimentSuite)
//! (worker-threaded, one engine per worker) rendered into tables, driven
//! both by `cargo bench --bench figN` and by the `ol4el figN` CLI. Fig. 6
//! goes beyond the paper: an engine-free fleet-scale sweep (edge count ×
//! network × churn) over [`FleetSim`](crate::net::FleetSim).

pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator;
use crate::engine::ComputeEngine;
use crate::strategy::StrategySpec;

/// The four strategies every paper figure compares (§V-A): the two OL4EL
/// manners plus the AC-sync and Fixed-I baselines.
pub fn paper_strategies() -> [StrategySpec; 4] {
    [
        StrategySpec::ol4el_sync(),
        StrategySpec::ol4el_async(),
        StrategySpec::ac_sync(),
        StrategySpec::fixed_i(),
    ]
}

// Engine selection lives with the engines and the aggregate shape with the
// coordinator; re-exported here because harness/bench call sites
// historically imported them from this module.
pub use crate::coordinator::Aggregate;
pub use crate::engine::{build_engine, EngineKind};

/// Run `cfg` across `seeds` and aggregate the headline numbers.
pub fn run_seeds(
    cfg: &RunConfig,
    engine: &dyn ComputeEngine,
    seeds: &[u64],
) -> Result<Aggregate> {
    assert!(!seeds.is_empty());
    let mut agg = Aggregate::empty();
    for &seed in seeds {
        let mut c = cfg.clone();
        c.seed = seed;
        let r = coordinator::run(&c, engine)?;
        agg.push(&r);
    }
    Ok(agg)
}

/// Shared sizing knobs for the figure benches: `quick` keeps `cargo bench`
/// wall-time reasonable; `full` mirrors the paper's sweep. `artifacts` is
/// where suite workers load HLO from when `engine` is PJRT.
#[derive(Clone, Debug)]
pub struct SweepOpts {
    /// `quick` keeps `cargo bench` wall-time reasonable; `full` mirrors
    /// the paper's sweep.
    pub quick: bool,
    /// Seeds per grid cell.
    pub seeds: u64,
    /// Compute engine driving the training sweeps.
    pub engine: EngineKind,
    /// HLO artifact directory for `EngineKind::Pjrt` suite workers.
    pub artifacts: String,
    /// Worker shards for the fleet sweeps (fig6); 0 = the [`FleetSim`]
    /// default, the host's available parallelism. Results are identical
    /// at any value — this only trades threads for wall-clock.
    ///
    /// [`FleetSim`]: crate::net::FleetSim
    pub shards: usize,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts {
            quick: true,
            seeds: 2,
            engine: EngineKind::Native,
            artifacts: "artifacts".to_string(),
            shards: 0,
        }
    }
}

impl SweepOpts {
    /// The concrete seed values (42, 43, …).
    pub fn seed_list(&self) -> Vec<u64> {
        (0..self.seeds.max(1)).map(|i| 42 + i).collect()
    }

    /// Training-set size scaled for bench speed (batch shape is fixed, so
    /// a smaller corpus only changes shard diversity, not step cost).
    pub fn data_n(&self) -> usize {
        if self.quick {
            6_000
        } else {
            20_000
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("PJRT"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("tpu"), None);
    }

    #[test]
    fn run_seeds_aggregates() {
        use crate::engine::native::NativeEngine;
        let engine = NativeEngine::default();
        let cfg = RunConfig {
            data_n: 3000,
            budget: 600.0,
            ..Default::default()
        };
        let agg = run_seeds(&cfg, &engine, &[1, 2]).unwrap();
        assert_eq!(agg.metric.count(), 2);
        assert_eq!(agg.updates.count(), 2);
        assert!(agg.metric.mean() > 0.0);
    }

    #[test]
    fn sweep_opts_sizes() {
        let q = SweepOpts::default();
        assert_eq!(q.data_n(), 6000);
        assert_eq!(q.seed_list(), vec![42, 43]);
        let f = SweepOpts {
            quick: false,
            seeds: 3,
            ..Default::default()
        };
        assert_eq!(f.data_n(), 20000);
        assert_eq!(f.seed_list().len(), 3);
    }
}
