//! Figure 5 — "Model Accuracy vs. Number of Edge Servers" (paper §V-B.3):
//! the scalability simulation, N from 3 to 100 edges under heterogeneity
//! H ∈ {1, 5, 10, 15}, as a declarative [`ExperimentSuite`] grid; (a)
//! K-means F1, (b) SVM accuracy; OL4EL-async at every (N, H) plus the
//! OL4EL-sync comparison. Claims this regenerates:
//!   * OL4EL-async improves with N (more aggregated information);
//!   * accuracy degrades as H rises (stale slow-edge updates);
//!   * OL4EL-sync wins at H=1 but collapses by H=15, where it is beaten by
//!     OL4EL-async.

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::{find_outcome, ExperimentSuite};
use crate::harness::SweepOpts;
use crate::model::{Learner as _, TaskSpec};
use crate::strategy::StrategySpec;
use crate::util::table::{f, Table};

/// Fleet sizes swept (N axis).
pub fn n_grid(quick: bool) -> Vec<usize> {
    if quick {
        vec![3, 10, 25]
    } else {
        vec![3, 10, 25, 50, 100]
    }
}

/// Heterogeneity ratios swept (H axis).
pub fn h_grid(quick: bool) -> Vec<f64> {
    if quick {
        vec![1.0, 15.0]
    } else {
        vec![1.0, 5.0, 10.0, 15.0]
    }
}

/// The run config of one (task, strategy, N, H) cell.
pub fn cell_config(
    task: &TaskSpec,
    strategy: &StrategySpec,
    n: usize,
    h: f64,
    opts: &SweepOpts,
) -> RunConfig {
    RunConfig {
        task: task.clone(),
        strategy: strategy.clone(),
        n_edges: n,
        hetero: h,
        // Simulation regime: unit-cost clock; same budget for every cell.
        budget: if opts.quick { 3000.0 } else { 5000.0 },
        data_n: opts.data_n().max(n * 40),
        ..Default::default()
    }
    .with_paper_utility()
}

/// The Fig. 5 grid: tasks × {async, sync} × fleet sizes × heterogeneity,
/// with `data_n` scaled to the fleet by [`cell_config`].
pub fn suite(opts: &SweepOpts) -> ExperimentSuite {
    let o = opts.clone();
    ExperimentSuite::new(
        "fig5",
        cell_config(&TaskSpec::kmeans(), &StrategySpec::ol4el_async(), 3, 1.0, opts),
    )
    .tasks([TaskSpec::kmeans(), TaskSpec::svm()])
    .strategies([StrategySpec::ol4el_async(), StrategySpec::ol4el_sync()])
    .fleet_sizes(n_grid(opts.quick))
    .heteros(h_grid(opts.quick))
    .seeds(opts.seed_list())
    .configure(move |cfg| {
        *cfg = cell_config(
            &cfg.task.clone(),
            &cfg.strategy.clone(),
            cfg.n_edges,
            cfg.hetero,
            &o,
        )
    })
}

/// Run the sweep and render its tables.
pub fn run(opts: &SweepOpts) -> Result<Vec<Table>> {
    let outcomes = suite(opts).run(opts.engine, &opts.artifacts)?;
    let ns = n_grid(opts.quick);
    let hs = h_grid(opts.quick);
    let mut tables = Vec::new();

    for task in [TaskSpec::kmeans(), TaskSpec::svm()] {
        let metric_name = task.learner().metric_name();
        let mut header: Vec<String> = vec!["N".into()];
        for &h in &hs {
            header.push(format!("async H={h:.0}"));
        }
        for &h in &hs {
            header.push(format!("sync H={h:.0}"));
        }
        let mut t = Table::new(
            format!(
                "Fig 5{}: {} {} vs number of edge servers",
                if task.name() == "kmeans" { "a" } else { "b" },
                task.name(),
                metric_name
            ),
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for &n in &ns {
            let mut row = vec![n.to_string()];
            for strategy in [StrategySpec::ol4el_async(), StrategySpec::ol4el_sync()] {
                for &h in &hs {
                    let outcome = find_outcome(&outcomes, &task, &strategy, n, h)
                        .ok_or_else(|| {
                            anyhow!("fig5: missing cell {task}/{strategy}/N={n}/H={h}")
                        })?;
                    row.push(f(outcome.agg.metric.mean(), 4));
                }
            }
            t.row(row);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_match_paper_ranges() {
        let ns = n_grid(false);
        assert_eq!(*ns.first().unwrap(), 3);
        assert_eq!(*ns.last().unwrap(), 100);
        let hs = h_grid(false);
        assert_eq!(hs, vec![1.0, 5.0, 10.0, 15.0]);
    }

    #[test]
    fn cell_config_scales_data_with_fleet() {
        let cfg = cell_config(
            &TaskSpec::svm(),
            &StrategySpec::ol4el_async(),
            100,
            15.0,
            &SweepOpts::default(),
        );
        assert!(cfg.data_n >= 100 * 40);
        assert_eq!(cfg.n_edges, 100);
    }

    #[test]
    fn suite_scales_data_per_cell() {
        let cells = suite(&SweepOpts::default()).cells();
        assert_eq!(cells.len(), 2 * 2 * n_grid(true).len() * h_grid(true).len());
        for (spec, cfg) in &cells {
            assert!(cfg.data_n >= spec.n_edges * 40, "N={}", spec.n_edges);
        }
    }
}
