//! `ol4el` — the leader binary: train runs, figure regeneration, artifact
//! inspection. Python never runs here; the PJRT engine loads AOT HLO from
//! artifacts/ (see `make artifacts`).

use anyhow::{anyhow, Result};

use ol4el::config::{legacy_strategy, PartitionKind, RunConfig};
use ol4el::coordinator::observer::from_fn;
use ol4el::coordinator::utility::UtilityKind;
use ol4el::coordinator::{checkpoint, ExperimentBuilder, RunEvent, RunResult, Session};
use ol4el::harness::{self, EngineKind, SweepOpts};
use ol4el::model::{Learner as _, TaskSpec};
use ol4el::net::wire::{
    accept_fleet_with, bench_loopback, serve_checkpoint_from, JoinOpts, WireServer,
};
use ol4el::net::{ChurnSpec, FleetSim, NetworkSpec, Topology};
use ol4el::sim::cost::CostMode;
use ol4el::sim::hetero::HeteroProfile;
use ol4el::strategy::StrategySpec;
use ol4el::util::cli::{
    Args, Cli, BANDIT_GRAMMAR, CHECKPOINT_GRAMMAR, STRATEGY_GRAMMAR, TOPOLOGY_GRAMMAR,
    WIRE_GRAMMAR,
};
use ol4el::util::json::Json;
use ol4el::util::table::{f, Table};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run_cli(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    format!(
        "ol4el — OL4EL edge-cloud collaborative learning (Han et al. 2020)\n\
         \n\
         Subcommands:\n\
           train               run one training configuration and print its trace\n\
           deploy              threaded testbed: one OS thread per edge, measured costs\n\
           fleet               engine-free sharded fleet simulation at 10k-100k edges\n\
                               (message-passing transport, network + churn models)\n\
           coordinator serve   real deployment: serve one session to remote edge\n\
                               processes over TCP (length-prefixed JSON frames)\n\
           edge join ADDR      real deployment: run one edge server process\n\
           fig3 .. fig6        regenerate a figure (tables + results/*.csv)\n\
           bench-tasks         per-task step/event throughput (BENCH_tasks.json)\n\
           bench-strategies    per-strategy decision-loop throughput\n\
                               (BENCH_strategies.json)\n\
           inspect-artifacts   show the AOT artifact manifest and PJRT platform\n\
           config              print the default config as JSON (edit + pass via --config)\n\
         \n\
         {}\n\
         Run `ol4el <subcommand> --help` for flags.\n",
        ol4el::util::cli::SPEC_GRAMMAR
    )
}

fn run_cli(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "deploy" => cmd_deploy(rest),
        "fleet" => cmd_fleet(rest),
        "coordinator" => cmd_coordinator(rest),
        "edge" => cmd_edge(rest),
        "fig3" | "fig4" | "fig5" | "fig6" => cmd_fig(cmd, rest),
        "bench-tasks" => cmd_bench_tasks(rest),
        "bench-strategies" => cmd_bench_strategies(rest),
        "inspect-artifacts" => cmd_inspect(rest),
        "config" => {
            println!("{}", RunConfig::default().to_json().pretty());
            Ok(())
        }
        "--help" | "-h" | "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}'\n\n{}", usage())),
    }
}

fn train_cli() -> Cli {
    Cli::new("ol4el train", "run one training configuration")
        .opt(
            "task",
            "svm",
            "task spec: svm | kmeans | logreg | gmm, parameterized NAME[:KEY=N]* \
             (e.g. kmeans:k=5, logreg:d=59:c=8, gmm:k=3; see the grammar below)",
        )
        .opt_no_default("strategy", STRATEGY_GRAMMAR)
        .opt(
            "algo",
            "ol4el-async",
            "legacy alias of --strategy: ol4el-sync | ol4el-async | ac-sync | fixed-i",
        )
        .opt("edges", "3", "number of edge servers")
        .opt("hetero", "1.0", "heterogeneity ratio H (>= 1)")
        .opt("hetero-profile", "linear", "linear | random")
        .opt("budget", "5000", "per-edge resource budget (ms)")
        .opt("cost-mode", "fixed", "fixed | variable[:CV] | measured")
        .opt("base-comp", "40", "nominal compute ms per local iteration")
        .opt("base-comm", "60", "nominal communication ms per global update")
        .opt("tau-max", "10", "longest global update interval (arm count)")
        .opt("lr", "0.05", "initial learning rate")
        .opt("reg", "0.0001", "L2 regularization")
        .opt("lr-decay", "0.02", "per-global-update learning-rate decay")
        .opt("utility", "eval", "eval | delta (learning utility definition)")
        .opt("bandit", "auto", BANDIT_GRAMMAR)
        .opt(
            "fixed-interval",
            "5",
            "legacy alias: interval for the fixed-i baseline (spec form: fixed-i:i=N)",
        )
        .opt(
            "partition",
            "iid",
            "iid | label-skew[:ALPHA]; ALPHA = Dirichlet concentration > 0, \
             default 0.5, smaller = more skew (e.g. label-skew:0.3)",
        )
        .opt("data-n", "20000", "training set size")
        .opt("separation", "2.5", "dataset difficulty: class/cluster separation")
        .opt("staleness-decay", "0.5", "async merge staleness decay exponent")
        .opt("async-alpha", "0.6", "async base mixing rate at a merge")
        .opt("eval-every", "1", "record a trace point every k global updates")
        .opt("failure-rate", "0", "per-round probability an edge fail-stops (async)")
        .opt(
            "network",
            "ideal",
            "ideal | fixed:MS | uniform:LO:HI | lognormal:MEDIAN:SIGMA, \
             plus [,bw:MBPS][,drop:P][,timeout:MS][,retries:N][,part:START-END] \
             (e.g. lognormal:5:0.5,drop:0.01)",
        )
        .opt(
            "churn",
            "none",
            "none | poisson:LEAVE[,join:RATE][,restart:MS][,straggle:P:FACTOR]; \
             rates are events per 1000 virtual ms (e.g. poisson:0.01,join:0.05)",
        )
        .opt("topology", "flat", TOPOLOGY_GRAMMAR)
        .opt("seed", "42", "PRNG seed")
        .opt("engine", "native", "native | pjrt (the full 3-layer path)")
        .opt("artifacts", "artifacts", "artifact directory for --engine pjrt")
        .opt_no_default("config", "load a JSON config file (flags override it)")
        .opt_no_default(
            "telemetry",
            "stream span/counter/histogram records to this JSONL file",
        )
        .opt(
            "telemetry-sample",
            "1",
            "record every Nth span (flush snapshots are never sampled)",
        )
        .opt(
            "checkpoint-every",
            "0",
            "write a resumable snapshot every N global updates (0 = off)",
        )
        .opt(
            "checkpoint-to",
            "checkpoint.json",
            "where --checkpoint-every writes the snapshot (atomic replace)",
        )
        .opt_no_default(
            "resume",
            "resume from a checkpoint file; the snapshot's embedded config is \
             the truth (run-shape flags must match it or stay at defaults)",
        )
        .switch("trace", "print every trace point")
        .switch("live", "stream global updates to stderr as they happen")
        .switch("json", "emit the result as JSON")
}

/// Resolve the strategy spec from the CLI flag set: `--strategy` wins;
/// otherwise the legacy `--algo` / `--bandit` / `--fixed-interval` alias
/// trio composes the same canonical spec the JSON wire fields would.
fn strategy_from_args(a: &Args) -> Result<StrategySpec> {
    if let Some(spec) = a.get("strategy") {
        return StrategySpec::parse(spec)
            .map_err(|e| anyhow!("bad --strategy '{spec}': {e} (grammar: {STRATEGY_GRAMMAR})"));
    }
    let algo = a.str("algo");
    let bandit = a.str("bandit");
    let fixed = a.usize("fixed-interval").map_err(|e| anyhow!(e))?;
    // The legacy flag trio stays exactly as strict as the enum-era CLI:
    // an out-of-range --fixed-interval fails for every --algo, even the
    // ones that discard it.
    let tau_max = a.usize("tau-max").map_err(|e| anyhow!(e))?;
    if fixed == 0 || fixed > tau_max {
        return Err(anyhow!(
            "--fixed-interval must be in 1..=tau-max ({tau_max})"
        ));
    }
    legacy_strategy(&algo, Some(&bandit), Some(fixed))
        .map_err(|e| anyhow!("{e} (bandit grammar: {BANDIT_GRAMMAR})"))
}

/// Assemble an [`ExperimentBuilder`] from the CLI flag set. `--config`
/// seeds the builder from the JSON wire format; every flag then overrides
/// through the typed setters (flags all carry defaults).
fn builder_from_args(a: &Args) -> Result<ExperimentBuilder> {
    let base = if let Some(path) = a.get("config") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config '{path}': {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing config '{path}': {e}"))?;
        RunConfig::from_json(&j)?
    } else {
        RunConfig::default()
    };
    let partition_spec = a.str("partition");
    Ok(ExperimentBuilder::from_config(base)
        .task(parse_task(&a.str("task"))?)
        .strategy(strategy_from_args(a)?)
        .edges(a.usize("edges").map_err(|e| anyhow!(e))?)
        .hetero(a.f64("hetero").map_err(|e| anyhow!(e))?)
        .hetero_profile(
            HeteroProfile::parse(&a.str("hetero-profile"))
                .ok_or_else(|| anyhow!("bad --hetero-profile"))?,
        )
        .budget(a.f64("budget").map_err(|e| anyhow!(e))?)
        .cost_mode(
            CostMode::parse(&a.str("cost-mode")).ok_or_else(|| anyhow!("bad --cost-mode"))?,
        )
        .base_costs(
            a.f64("base-comp").map_err(|e| anyhow!(e))?,
            a.f64("base-comm").map_err(|e| anyhow!(e))?,
        )
        .tau_max(a.usize("tau-max").map_err(|e| anyhow!(e))?)
        .lr(a.f64("lr").map_err(|e| anyhow!(e))? as f32)
        .reg(a.f64("reg").map_err(|e| anyhow!(e))? as f32)
        .lr_decay(a.f64("lr-decay").map_err(|e| anyhow!(e))? as f32)
        .utility(
            UtilityKind::parse(&a.str("utility")).ok_or_else(|| anyhow!("bad --utility"))?,
        )
        .partition(PartitionKind::parse(&partition_spec).ok_or_else(|| {
            anyhow!("bad --partition '{partition_spec}' (grammar: iid | label-skew[:ALPHA])")
        })?)
        .data_n(a.usize("data-n").map_err(|e| anyhow!(e))?)
        .separation(a.f64("separation").map_err(|e| anyhow!(e))?)
        .staleness_decay(a.f64("staleness-decay").map_err(|e| anyhow!(e))?)
        .async_alpha(a.f64("async-alpha").map_err(|e| anyhow!(e))?)
        .eval_every(a.usize("eval-every").map_err(|e| anyhow!(e))?)
        .failure_rate(a.f64("failure-rate").map_err(|e| anyhow!(e))?)
        .network(parse_network(&a.str("network"))?)
        .churn(parse_churn(&a.str("churn"))?)
        .topology(parse_topology(&a.str("topology"))?)
        .seed(a.u64("seed").map_err(|e| anyhow!(e))?))
}

/// Install the JSONL telemetry sink from the shared flag pair
/// (`--telemetry FILE`, `--telemetry-sample N`). Returns whether a sink
/// was installed; the sample rate applies either way.
fn telemetry_from_args(a: &Args) -> Result<bool> {
    let sample = a.u64("telemetry-sample").map_err(|e| anyhow!(e))? as u32;
    ol4el::telemetry::set_sample(sample);
    let Some(path) = a.get("telemetry") else {
        return Ok(false);
    };
    ol4el::telemetry::install_jsonl(path, sample)
        .map_err(|e| anyhow!("opening --telemetry '{path}': {e}"))?;
    Ok(true)
}

/// End-of-command telemetry epilogue: flush instrument snapshots into
/// the sink, print the summary table to stderr at `--log info`, and
/// close the sink. No-op when `--telemetry` wasn't given.
fn telemetry_finish(installed: bool) {
    if !installed {
        return;
    }
    ol4el::telemetry::flush();
    if ol4el::util::logging::enabled(ol4el::util::logging::Level::Info) {
        eprint!("{}", ol4el::telemetry::report());
    }
    ol4el::telemetry::uninstall();
}

fn parse_task(spec: &str) -> Result<TaskSpec> {
    TaskSpec::parse(spec)
        .map_err(|e| anyhow!("bad --task '{spec}': {e} (grammar: NAME[:KEY=N]*, e.g. kmeans:k=5)"))
}

fn parse_network(spec: &str) -> Result<NetworkSpec> {
    NetworkSpec::parse(spec).ok_or_else(|| {
        anyhow!(
            "bad --network '{spec}' (grammar: ideal | fixed:MS | uniform:LO:HI | \
             lognormal:MEDIAN:SIGMA[,bw:MBPS][,drop:P][,timeout:MS][,retries:N][,part:START-END])"
        )
    })
}

fn parse_churn(spec: &str) -> Result<ChurnSpec> {
    ChurnSpec::parse(spec).ok_or_else(|| {
        anyhow!(
            "bad --churn '{spec}' (grammar: none | \
             poisson:LEAVE[,join:RATE][,restart:MS][,straggle:P:FACTOR])"
        )
    })
}

fn parse_topology(spec: &str) -> Result<Topology> {
    Topology::parse(spec)
        .ok_or_else(|| anyhow!("bad --topology '{spec}' (grammar: {TOPOLOGY_GRAMMAR})"))
}

/// Load the `--resume` checkpoint document and refuse a flag set that
/// contradicts it: the snapshot's embedded config is the truth on resume,
/// so the run-shape flags must either spell out the checkpoint's own
/// config (fingerprint-equal) or stay untouched at the parser defaults.
/// Flags outside [`RunConfig`] (`--engine`, `--telemetry`, `--json`, the
/// checkpoint flags themselves) are free to vary.
fn load_resume(a: &Args, path: &str, defaults: &Cli) -> Result<Json> {
    let doc = checkpoint::load(std::path::Path::new(path))
        .map_err(|e| anyhow!("loading --resume '{path}': {e}"))?;
    let flags = builder_from_args(a)?.build()?.into_config().fingerprint();
    let ckpt = checkpoint::config_of(&doc)?.fingerprint();
    if flags != ckpt {
        let empty = defaults
            .parse(&[])
            .map_err(|e| anyhow!(e))?
            .ok_or_else(|| anyhow!("--help in an empty argv"))?;
        let baseline = builder_from_args(&empty)?.build()?.into_config().fingerprint();
        if flags != baseline {
            return Err(anyhow!(
                "--resume '{path}': the flag set contradicts the checkpoint's \
                 config; drop the run-shape flags (the snapshot carries the \
                 full config) or pass exactly the flags the checkpointed run \
                 used"
            ));
        }
    }
    Ok(doc)
}

/// Shared `--checkpoint-every` / `--checkpoint-to` wiring for `train` and
/// `coordinator serve`: arm the session's periodic snapshot writer.
/// Returns the armed path (`None` when checkpointing is off).
fn checkpoint_from_args(a: &Args, session: &mut Session<'_>) -> Result<Option<String>> {
    let every = a.u64("checkpoint-every").map_err(|e| anyhow!(e))?;
    if every == 0 {
        return Ok(None);
    }
    let path = a.str("checkpoint-to");
    session.set_checkpoint(every, &path);
    Ok(Some(path))
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let Some(a) = train_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let engine_kind =
        EngineKind::parse(&a.str("engine")).ok_or_else(|| anyhow!("bad --engine"))?;
    let engine = harness::build_engine(engine_kind, &a.str("artifacts"))?;
    let mut session = match a.get("resume") {
        Some(path) => {
            let doc = load_resume(&a, path, &train_cli())?;
            Session::resume(&doc, engine.as_ref())?
        }
        None => builder_from_args(&a)?.build()?.session(engine.as_ref())?,
    };
    let cfg = session.cfg().clone();
    checkpoint_from_args(&a, &mut session)?;
    if a.flag("live") {
        // Streaming observer: narrate every recorded global update and
        // every edge retirement while the run is still going.
        session.observe(from_fn(|ev: &RunEvent| match ev {
            RunEvent::GlobalUpdate { point } => eprintln!(
                "[live] t={:>8.0}ms  spent={:>7.0}ms  updates={:>5}  metric={:.4}",
                point.wall_ms, point.mean_spent, point.updates, point.metric
            ),
            RunEvent::EdgeRetired { edge, wall_ms, spent } => {
                eprintln!("[live] edge {edge} retired at t={wall_ms:.0}ms ({spent:.0}ms spent)")
            }
            _ => {}
        }));
    }

    eprintln!(
        "[ol4el] task={} strategy={} edges={} H={} budget={}ms engine={}{}",
        cfg.task.name(),
        cfg.strategy.label(),
        cfg.n_edges,
        cfg.hetero,
        cfg.budget,
        engine_kind.name(),
        if a.get("resume").is_some() { " (resumed)" } else { "" }
    );
    let tele = telemetry_from_args(&a)?;
    let t0 = std::time::Instant::now();
    let r = session.run()?;
    let dt = t0.elapsed().as_secs_f64();
    let out = report_run(&a, &cfg, &r, dt);
    telemetry_finish(tele);
    out
}

/// Post-run reporting shared by `train` and `coordinator serve`: the
/// `--json` document, the `--trace` table and the summary lines. One
/// format on purpose — the distributed run's output is diffable against
/// the in-process run's (`tests/wire_e2e.rs` asserts everything but
/// `host_seconds` is bit-identical).
fn report_run(a: &Args, cfg: &RunConfig, r: &RunResult, dt: f64) -> Result<()> {
    if a.flag("json") {
        let trace = Json::arr(r.trace.iter().map(|p| {
            Json::obj(vec![
                ("wall_ms", Json::num(p.wall_ms)),
                ("mean_spent", Json::num(p.mean_spent)),
                ("updates", Json::num(p.updates as f64)),
                ("metric", Json::num(p.metric)),
            ])
        }));
        let out = Json::obj(vec![
            ("config", cfg.to_json()),
            ("final_metric", Json::num(r.final_metric)),
            ("updates", Json::num(r.total_updates as f64)),
            ("wall_ms", Json::num(r.wall_ms)),
            ("mean_spent", Json::num(r.mean_spent)),
            ("retired_edges", Json::num(r.retired_edges as f64)),
            ("trace", trace),
            ("host_seconds", Json::num(dt)),
        ]);
        println!("{}", out.pretty());
        return Ok(());
    }

    if a.flag("trace") {
        let mut t = Table::new("trace", &["wall_ms", "mean_spent", "updates", "metric"]);
        for p in &r.trace {
            t.row(vec![
                f(p.wall_ms, 1),
                f(p.mean_spent, 1),
                p.updates.to_string(),
                f(p.metric, 4),
            ]);
        }
        print!("{}", t.render());
    }
    let metric_name = cfg.task.learner().metric_name();
    println!(
        "final {metric_name}={:.4}  global_updates={}  virtual_wall={:.0}ms  mean_spent={:.0}ms  retired={}/{}  host={:.2}s",
        r.final_metric, r.total_updates, r.wall_ms, r.mean_spent, r.retired_edges, r.n_edges, dt
    );
    println!(
        "tau histogram (τ=1..{}): {:?}",
        r.tau_histogram.len(),
        r.tau_histogram
    );
    Ok(())
}

fn deploy_cli() -> Cli {
    // The threaded testbed reuses the train flag set plus the two
    // data-parallelism knobs.
    train_cli()
        .opt(
            "threads",
            "1",
            "engine kernel threads ('max' or 0 = all host cores)",
        )
        .opt(
            "edge-batch",
            "1",
            "edges per worker thread (1 = one OS thread per edge; larger \
             groups batch same-interval rounds through local_step_batch)",
        )
}

/// Parse a `--threads` value: a number, or `max`/`0` for all host cores.
fn parse_threads(s: &str) -> Result<usize> {
    if s == "max" {
        return Ok(0);
    }
    s.parse()
        .map_err(|_| anyhow!("bad --threads '{s}' (expected a number or 'max')"))
}

fn cmd_deploy(argv: &[String]) -> Result<()> {
    // Budgets are measured milliseconds of real (slowdown-scaled)
    // wall-clock.
    let Some(a) = deploy_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let mut cfg = builder_from_args(&a)?.build()?.into_config();
    cfg.cost.mode = CostMode::Measured;
    let threads = ol4el::engine::pool::set_threads(parse_threads(&a.str("threads"))?);
    let edge_batch = a.usize("edge-batch").map_err(|e| anyhow!(e))?.max(1);
    let engine = harness::build_engine(
        EngineKind::parse(&a.str("engine")).ok_or_else(|| anyhow!("bad --engine"))?,
        &a.str("artifacts"),
    )?;
    eprintln!(
        "[ol4el] threaded deploy: {} edges, H={}, budget {} ms (measured), \
         {threads} engine threads, edge-batch {edge_batch}",
        cfg.n_edges, cfg.hetero, cfg.budget
    );
    let r = ol4el::deploy::run_threaded_batched(&cfg, engine.as_ref(), edge_batch)?;
    println!(
        "final metric {:.4}  updates={}  host={:.2}s",
        r.final_metric, r.total_updates, r.host_seconds
    );
    for (i, (spent, rounds)) in r.per_edge_spent.iter().zip(&r.per_edge_rounds).enumerate() {
        println!("  edge {i}: {rounds} rounds, {spent:.1} ms spent");
    }
    Ok(())
}

fn coordinator_usage() -> String {
    format!(
        "ol4el coordinator — real networked deployment: the cloud side\n\
         \n\
         Subcommands:\n\
           serve    listen on --addr, gather the fleet, run one session over TCP\n\
           stats    scrape one live telemetry snapshot from a running coordinator\n\
         \n\
         Grammar: {WIRE_GRAMMAR}\n\
         \n\
         Checkpoints: {CHECKPOINT_GRAMMAR}\n\
         \n\
         Run `ol4el coordinator serve --help` for flags.\n"
    )
}

fn cmd_coordinator(argv: &[String]) -> Result<()> {
    match argv.first().map(String::as_str) {
        Some("serve") => cmd_serve(&argv[1..]),
        Some("stats") => cmd_stats(&argv[1..]),
        None | Some("--help") | Some("-h") | Some("help") => {
            print!("{}", coordinator_usage());
            Ok(())
        }
        Some(other) => Err(anyhow!(
            "unknown coordinator subcommand '{other}'\n\n{}",
            coordinator_usage()
        )),
    }
}

fn stats_cli() -> Cli {
    Cli::new(
        "ol4el coordinator stats",
        "connect, send one Stats frame, print the coordinator's live telemetry snapshot",
    )
    .opt("addr", "127.0.0.1:7070", "HOST:PORT of the running coordinator")
    .opt("format", "json", "json | prom (Prometheus text exposition)")
    .opt("timeout-ms", "5000", "ms to wait for the StatsReply")
}

/// `coordinator stats` — the live metrics endpoint's client: one `Stats`
/// frame in, one `StatsReply` out, rendered as JSON or Prometheus text.
/// Works against any wire listener (pre-Hello and mid-session alike).
fn cmd_stats(argv: &[String]) -> Result<()> {
    use ol4el::net::wire::{Frame, FrameReader, WireError};
    let Some(a) = stats_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let addr = a.str("addr");
    let timeout = std::time::Duration::from_millis(a.u64("timeout-ms").map_err(|e| anyhow!(e))?);
    let stream =
        std::net::TcpStream::connect(&addr).map_err(|e| anyhow!("connecting {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).ok();
    let mut write_half = stream
        .try_clone()
        .map_err(|e| anyhow!("cloning socket: {e}"))?;
    ol4el::net::wire::write_frame(&mut write_half, &Frame::Stats)
        .map_err(|e| anyhow!("sending stats request: {e}"))?;
    let mut fr = FrameReader::new();
    let mut read_half = &stream;
    loop {
        match fr.read_frame(&mut read_half) {
            Ok(Frame::StatsReply { metrics }) => {
                match a.str("format").as_str() {
                    "json" => println!("{}", metrics.pretty()),
                    "prom" => print!("{}", prom_from_snapshot(&metrics)),
                    other => return Err(anyhow!("bad --format '{other}' (json | prom)")),
                }
                return Ok(());
            }
            Ok(_) => {} // a busy session may interleave other frames; keep reading
            Err(WireError::Timeout) => {
                return Err(anyhow!("no StatsReply within {}ms", timeout.as_millis()))
            }
            Err(e) => return Err(anyhow!("reading stats reply: {e}")),
        }
    }
}

/// Render a remote [`telemetry::snapshot`] JSON document as Prometheus
/// text exposition (the local-registry renderer lives in
/// `telemetry::prometheus`; this one works on the scraped snapshot).
///
/// [`telemetry::snapshot`]: ol4el::telemetry::snapshot
fn prom_from_snapshot(metrics: &Json) -> String {
    fn name_of(s: &str) -> String {
        s.chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect()
    }
    let mut out = String::new();
    let section = |j: &Json, key: &str| -> Vec<(String, Json)> {
        match j.get(key) {
            Some(Json::Obj(m)) => m.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            _ => Vec::new(),
        }
    };
    for (k, v) in section(metrics, "counters") {
        let n = name_of(&k);
        out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
    }
    for (k, v) in section(metrics, "gauges") {
        let n = name_of(&k);
        let val = v.get("value").cloned().unwrap_or(Json::num(0.0));
        out.push_str(&format!("# TYPE {n} gauge\n{n} {val}\n"));
    }
    for (k, v) in section(metrics, "histograms") {
        let n = name_of(&k);
        out.push_str(&format!("# TYPE {n} summary\n"));
        for field in ["count", "mean_us", "p50_us", "p99_us", "max_us"] {
            if let Some(val) = v.get(field) {
                out.push_str(&format!("{n}_{field} {val}\n"));
            }
        }
    }
    out
}

/// `coordinator serve` = the full `train` flag set plus the listen
/// address and the crash-handling windows: the served session is the
/// same experiment a local `train` would run.
fn serve_cli() -> Cli {
    let mut cli = train_cli()
        .opt("addr", "127.0.0.1:7070", "HOST:PORT to listen on for edge joins")
        .opt(
            "round-timeout-ms",
            "30000",
            "ms to wait for a round's report before declaring the edge crashed",
        )
        .opt(
            "rejoin-window-ms",
            "10000",
            "ms a crashed edge may rejoin before being retired for good",
        );
    cli.name = "ol4el coordinator serve";
    cli.about = "serve one training session to remote edge processes over TCP";
    cli
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let Some(a) = serve_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let engine_kind =
        EngineKind::parse(&a.str("engine")).ok_or_else(|| anyhow!("bad --engine"))?;
    let engine = harness::build_engine(engine_kind, &a.str("artifacts"))?;
    let resuming = a.get("resume").is_some();
    let mut session = match a.get("resume") {
        Some(path) => {
            let doc = load_resume(&a, path, &serve_cli())?;
            Session::resume(&doc, engine.as_ref())?
        }
        None => builder_from_args(&a)?.build()?.session(engine.as_ref())?,
    };
    let cfg = session.cfg().clone();
    if !cfg.network.is_ideal() || !cfg.churn.is_none() {
        return Err(anyhow!(
            "coordinator serve runs on a real network: --network must stay 'ideal' and \
             --churn 'none' (the simulated models belong to `train` and `fleet`; \
             real latency and real crashes come in over the wire)"
        ));
    }
    let addr = a.str("addr");
    let listener =
        std::net::TcpListener::bind(&addr).map_err(|e| anyhow!("binding {addr}: {e}"))?;
    let local = listener
        .local_addr()
        .map_err(|e| anyhow!("local addr: {e}"))?;
    eprintln!(
        "[ol4el] coordinator: listening on {local} for {} edges (task={} strategy={}{})",
        cfg.n_edges,
        cfg.task.name(),
        cfg.strategy.label(),
        if resuming { ", resumed" } else { "" }
    );
    let fleet = accept_fleet_with(&listener, cfg.n_edges, resuming)
        .map_err(|e| anyhow!("gathering the fleet: {e}"))?;
    if resuming {
        // On --resume the checkpoint's slowdown vector is the truth:
        // Hello overrides are ignored so the restored strategy state
        // keeps pricing the arms it was trained on.
        for (i, p) in fleet.iter().enumerate() {
            if p.slowdown.is_some_and(|s| s != session.world.slowdowns[i]) {
                eprintln!(
                    "[ol4el] coordinator: edge {i} reported a slowdown override — \
                     ignored; the checkpoint pins the slowdown vector"
                );
            }
        }
    } else {
        // Hello-reported slowdown overrides replace the hetero profile's
        // value for that edge. The strategy prices arms off the slowdown
        // vector, so rebuild it before any select sees the stale profile.
        let mut overridden = false;
        for (i, p) in fleet.iter().enumerate() {
            if let Some(s) = p.slowdown {
                session.world.slowdowns[i] = s;
                session.world.edges[i].slowdown = s;
                overridden = true;
            }
        }
        if overridden {
            session.strategy = ol4el::strategy::build(&cfg, &session.world.slowdowns)?;
        }
    }
    // The banked iteration count each edge must fast-forward past on
    // welcome: all zeros on a fresh run, the checkpoint's `iters_done`
    // on a --resume.
    let iters: Vec<u64> = session.world.edges.iter().map(|e| e.iters_done).collect();
    let server = WireServer::start(
        listener,
        fleet,
        cfg.to_json(),
        session.world.slowdowns.clone(),
        iters,
        std::time::Duration::from_millis(a.u64("round-timeout-ms").map_err(|e| anyhow!(e))?),
        std::time::Duration::from_millis(a.u64("rejoin-window-ms").map_err(|e| anyhow!(e))?),
    )
    .map_err(|e| anyhow!("starting the wire server: {e}"))?;
    session.set_remote(Box::new(server));
    if let Some(path) = checkpoint_from_args(&a, &mut session)? {
        // Publish the snapshot file through the wire's CheckpointReq
        // endpoint so a restarted coordinator (or a curious client) can
        // fetch the latest document without filesystem access.
        serve_checkpoint_from(path);
    }
    if a.flag("live") {
        session.observe(from_fn(|ev: &RunEvent| match ev {
            RunEvent::GlobalUpdate { point } => eprintln!(
                "[live] t={:>8.0}ms  spent={:>7.0}ms  updates={:>5}  metric={:.4}",
                point.wall_ms, point.mean_spent, point.updates, point.metric
            ),
            RunEvent::EdgeJoined { edge, wall_ms } => {
                eprintln!("[live] edge {edge} rejoined at t={wall_ms:.0}ms")
            }
            RunEvent::EdgeRetired { edge, wall_ms, spent } => {
                eprintln!("[live] edge {edge} retired at t={wall_ms:.0}ms ({spent:.0}ms spent)")
            }
            _ => {}
        }));
    }
    eprintln!("[ol4el] coordinator: fleet complete — running");
    let tele = telemetry_from_args(&a)?;
    let t0 = std::time::Instant::now();
    let r = session.run()?;
    let dt = t0.elapsed().as_secs_f64();
    let out = report_run(&a, &cfg, &r, dt);
    telemetry_finish(tele);
    out
}

fn edge_usage() -> String {
    format!(
        "ol4el edge — real networked deployment: one edge server process\n\
         \n\
         Subcommands:\n\
           join ADDR    connect to a coordinator and serve local rounds\n\
         \n\
         Grammar: {WIRE_GRAMMAR}\n\
         \n\
         Run `ol4el edge join --help` for flags.\n"
    )
}

fn cmd_edge(argv: &[String]) -> Result<()> {
    match argv.first().map(String::as_str) {
        Some("join") => cmd_edge_join(&argv[1..]),
        None | Some("--help") | Some("-h") | Some("help") => {
            print!("{}", edge_usage());
            Ok(())
        }
        Some(other) => Err(anyhow!(
            "unknown edge subcommand '{other}'\n\n{}",
            edge_usage()
        )),
    }
}

fn edge_join_cli() -> Cli {
    Cli::new(
        "ol4el edge join",
        "join a coordinator as one edge server process (positional: ADDR = HOST:PORT)",
    )
    .opt_no_default(
        "slowdown",
        "heterogeneity slowdown override (>= 1) reported at join",
    )
    .opt_no_default("leave-after", "send a clean Leave after completing N rounds")
    .opt_no_default(
        "drop-round",
        "chaos: drop the connection without reporting round N, once, then rejoin",
    )
    .opt_no_default("rejoin", "rejoin a running session as this edge id")
    .opt("max-backoff-ms", "2000", "reconnect backoff ceiling (ms)")
    .opt("max-attempts", "40", "connection attempts before giving up")
    .opt("engine", "native", "native | pjrt (the full 3-layer path)")
    .opt("artifacts", "artifacts", "artifact directory for --engine pjrt")
    .opt_no_default(
        "telemetry",
        "stream span/counter/histogram records to this JSONL file",
    )
    .opt(
        "telemetry-sample",
        "1",
        "record every Nth span (flush snapshots are never sampled)",
    )
}

fn cmd_edge_join(argv: &[String]) -> Result<()> {
    let Some(a) = edge_join_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let Some(addr) = a.positional.first() else {
        return Err(anyhow!(
            "edge join: missing ADDR (HOST:PORT; see `ol4el edge join --help`)"
        ));
    };
    let opt_f64 = |k: &str| -> Result<Option<f64>> {
        a.get(k)
            .map(|s| {
                s.parse::<f64>()
                    .map_err(|_| anyhow!("--{k}: expected a number"))
            })
            .transpose()
    };
    let opt_u64 = |k: &str| -> Result<Option<u64>> {
        a.get(k)
            .map(|s| {
                s.parse::<u64>()
                    .map_err(|_| anyhow!("--{k}: expected a u64"))
            })
            .transpose()
    };
    let opt_usize = |k: &str| -> Result<Option<usize>> {
        a.get(k)
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|_| anyhow!("--{k}: expected an unsigned integer"))
            })
            .transpose()
    };
    let opts = JoinOpts {
        slowdown: opt_f64("slowdown")?,
        leave_after: opt_u64("leave-after")?,
        drop_round: opt_u64("drop-round")?,
        rejoin: opt_usize("rejoin")?,
        max_backoff_ms: a.u64("max-backoff-ms").map_err(|e| anyhow!(e))?,
        max_attempts: a.u64("max-attempts").map_err(|e| anyhow!(e))? as u32,
    };
    let engine = harness::build_engine(
        EngineKind::parse(&a.str("engine")).ok_or_else(|| anyhow!("bad --engine"))?,
        &a.str("artifacts"),
    )?;
    let tele = telemetry_from_args(&a)?;
    let out = ol4el::net::wire::join(addr, &opts, engine.as_ref());
    telemetry_finish(tele);
    out
}

fn fleet_cli() -> Cli {
    Cli::new(
        "ol4el fleet",
        "engine-free fleet simulation: the OL4EL protocol + transport at scale",
    )
    .opt("edges", "5000", "fleet size at t=0")
    .opt(
        "task",
        "svm",
        "task spec carried by the fleet config (protocol-only sim: any \
         registered task, e.g. logreg — validated, not trained)",
    )
    .opt("mode", "async", "async | sync | both (collaboration manner)")
    .opt("hetero", "4.0", "heterogeneity ratio H (>= 1)")
    .opt("hetero-profile", "linear", "linear | random")
    .opt("budget", "5000", "per-edge resource budget (ms)")
    .opt("cost-mode", "fixed", "fixed | variable[:CV] (no engine to measure)")
    .opt("base-comp", "40", "nominal compute ms per local iteration")
    .opt("base-comm", "60", "nominal communication ms per global update")
    .opt("tau-max", "10", "longest global update interval (arm count)")
    .opt("strategy", "ol4el", STRATEGY_GRAMMAR)
    .opt("bandit", "auto", BANDIT_GRAMMAR)
    .opt(
        "network",
        "lognormal:5:0.5",
        "network spec (see `ol4el --help` for the grammar)",
    )
    .opt("churn", "none", "churn spec (see `ol4el --help` for the grammar)")
    .opt("topology", "flat", TOPOLOGY_GRAMMAR)
    .opt("model-bytes", "4096", "serialized model size driving transfer times")
    .opt("eval-every", "100", "emit a GlobalUpdate trace point every k updates")
    .opt("failure-rate", "0", "per-launch probability an edge fail-stops")
    .opt(
        "shards",
        "0",
        "worker threads to shard the fleet over (0 = available parallelism); \
         results are bit-identical at any value",
    )
    .opt("seed", "42", "PRNG seed")
    .opt_no_default(
        "telemetry",
        "stream span/counter/histogram records to this JSONL file",
    )
    .opt(
        "telemetry-sample",
        "1",
        "record every Nth span (flush snapshots are never sampled)",
    )
    .opt("bench-out", "BENCH_fleet.json", "where --smoke writes its numbers")
    .opt(
        "wire-bench-out",
        "BENCH_wire.json",
        "where --smoke writes the TCP loopback wire measurement",
    )
    .opt(
        "wire-frames",
        "2000",
        "round trips the --smoke wire bench pushes through 127.0.0.1",
    )
    .switch(
        "smoke",
        "perf smoke: run sync+async at 1 shard and at --shards, assert bit-equal \
         results, write bench JSON with the speedup",
    )
    .switch("live", "stream joins/retirements/drops to stderr")
    .switch("json", "emit the report as JSON")
}

/// Assemble the fleet config from the CLI flag set. `--mode` (the `sync`
/// flag here) pins the strategy spec's manner via
/// [`StrategySpec::with_mode`]; the legacy `--bandit` alias parameterizes
/// the default `ol4el` strategy.
fn fleet_config(a: &Args, sync: bool) -> Result<RunConfig> {
    let n_edges = a.usize("edges").map_err(|e| anyhow!(e))?;
    let strategy_spec = a.str("strategy");
    let bandit_spec = a.str("bandit");
    let base_strategy = if bandit_spec != "auto" {
        // The legacy --bandit alias only parameterizes the default ol4el
        // strategy; combining it with an explicit non-default --strategy
        // is ambiguous — refuse rather than silently drop one of them.
        if strategy_spec != "ol4el" {
            return Err(anyhow!(
                "--bandit '{bandit_spec}' conflicts with --strategy '{strategy_spec}'; \
                 fold the bandit into the spec (ol4el:bandit=B[:eps=E])"
            ));
        }
        legacy_strategy("ol4el-async", Some(&bandit_spec), None)
            .map_err(|e| anyhow!("{e} (bandit grammar: {BANDIT_GRAMMAR})"))?
    } else {
        StrategySpec::parse(&strategy_spec).map_err(|e| {
            anyhow!("bad --strategy '{strategy_spec}': {e} (grammar: {STRATEGY_GRAMMAR})")
        })?
    };
    let strategy = base_strategy
        .with_mode(sync)
        .map_err(|e| anyhow!("--strategy '{strategy_spec}' with --mode: {e}"))?;
    let defaults = RunConfig::default();
    let mut cost = defaults.cost;
    cost.mode = CostMode::parse(&a.str("cost-mode")).ok_or_else(|| anyhow!("bad --cost-mode"))?;
    cost.base_comp = a.f64("base-comp").map_err(|e| anyhow!(e))?;
    cost.base_comm = a.f64("base-comm").map_err(|e| anyhow!(e))?;
    let task = parse_task(&a.str("task"))?;
    let eval_n = task.learner().eval_batch();
    Ok(RunConfig {
        task,
        strategy,
        n_edges,
        hetero: a.f64("hetero").map_err(|e| anyhow!(e))?,
        hetero_profile: HeteroProfile::parse(&a.str("hetero-profile"))
            .ok_or_else(|| anyhow!("bad --hetero-profile"))?,
        budget: a.f64("budget").map_err(|e| anyhow!(e))?,
        cost,
        tau_max: a.usize("tau-max").map_err(|e| anyhow!(e))?,
        network: parse_network(&a.str("network"))?,
        churn: parse_churn(&a.str("churn"))?,
        topology: parse_topology(&a.str("topology"))?,
        eval_every: a.usize("eval-every").map_err(|e| anyhow!(e))?.max(1),
        failure_rate: a.f64("failure-rate").map_err(|e| anyhow!(e))?,
        seed: a.u64("seed").map_err(|e| anyhow!(e))?,
        // The fleet trains no model; keep validate()'s dataset-sizing
        // invariants (eval split + per-edge coverage) satisfied at any
        // fleet size without generating anything.
        data_n: defaults.data_n.max(n_edges + eval_n),
        ..defaults
    })
}

fn run_fleet(
    a: &Args,
    sync: bool,
    shards_override: Option<usize>,
) -> Result<ol4el::net::FleetReport> {
    let mut sim = FleetSim::new(fleet_config(a, sync)?)?
        .model_bytes(a.f64("model-bytes").map_err(|e| anyhow!(e))?);
    let shards = match shards_override {
        Some(n) => n,
        None => a.usize("shards").map_err(|e| anyhow!(e))?,
    };
    if shards > 0 {
        sim = sim.shards(shards);
    }
    if a.flag("live") {
        sim = sim.observe(from_fn(|ev: &RunEvent| match ev {
            RunEvent::EdgeJoined { edge, wall_ms } => {
                eprintln!("[fleet] edge {edge} joined at t={wall_ms:.0}ms")
            }
            RunEvent::EdgeRetired { edge, wall_ms, spent } => {
                eprintln!("[fleet] edge {edge} retired at t={wall_ms:.0}ms ({spent:.0}ms spent)")
            }
            RunEvent::MessageDropped { edge, wall_ms, attempts, lost } => eprintln!(
                "[fleet] edge {edge}: {attempts} drops at t={wall_ms:.0}ms{}",
                if *lost { " (LOST)" } else { "" }
            ),
            RunEvent::GlobalUpdate { point } => eprintln!(
                "[fleet] t={:>9.0}ms updates={:>7} progress={:.3}",
                point.wall_ms, point.updates, point.metric
            ),
            _ => {}
        }));
    }
    sim.run()
}

fn fleet_report_json(r: &ol4el::net::FleetReport) -> Json {
    Json::obj(vec![
        ("edges", Json::num(r.n_edges as f64)),
        ("joined", Json::num(r.joined as f64)),
        ("retired", Json::num(r.retired as f64)),
        ("updates", Json::num(r.updates as f64)),
        ("virtual_wall_ms", Json::num(r.wall_ms)),
        ("mean_spent_ms", Json::num(r.mean_spent)),
        ("messages_sent", Json::num(r.messages_sent as f64)),
        ("messages_lost", Json::num(r.messages_lost as f64)),
        ("dropped_attempts", Json::num(r.dropped_attempts as f64)),
        ("events", Json::num(r.events as f64)),
        ("events_per_sec", Json::num(r.events_per_sec())),
        ("peak_queue_depth", Json::num(r.peak_queue_depth as f64)),
        ("shards", Json::num(r.shards as f64)),
        ("setup_seconds", Json::num(r.setup_seconds)),
        ("loop_seconds", Json::num(r.loop_seconds)),
        ("host_seconds", Json::num(r.host_seconds)),
    ])
}

fn print_fleet_report(mode: &str, r: &ol4el::net::FleetReport) {
    println!(
        "[{mode}] edges={} (+{} joined)  updates={}  virtual_wall={:.0}ms  mean_spent={:.0}ms",
        r.n_edges, r.joined, r.updates, r.wall_ms, r.mean_spent
    );
    println!(
        "[{mode}] messages={} (lost {}, {} dropped attempts)  events={} ({:.2} M/s)  \
         peak_queue={}  shards={}  setup={:.2}s loop={:.2}s",
        r.messages_sent,
        r.messages_lost,
        r.dropped_attempts,
        r.events,
        r.events_per_sec() / 1e6,
        r.peak_queue_depth,
        r.shards,
        r.setup_seconds,
        r.loop_seconds
    );
}

fn cmd_fleet(argv: &[String]) -> Result<()> {
    let Some(a) = fleet_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let tele = telemetry_from_args(&a)?;
    if a.flag("smoke") {
        let out = cmd_fleet_smoke(&a);
        telemetry_finish(tele);
        return out;
    }
    let mode = a.str("mode");
    let runs: Vec<(&str, bool)> = match mode.as_str() {
        "async" => vec![("async", false)],
        "sync" => vec![("sync", true)],
        "both" => vec![("sync", true), ("async", false)],
        other => return Err(anyhow!("bad --mode '{other}' (async | sync | both)")),
    };
    let mut out = Vec::new();
    for (name, sync) in runs {
        let r = run_fleet(&a, sync, None)?;
        print_fleet_report(name, &r);
        out.push((name, r));
    }
    if a.flag("json") {
        let j = Json::obj(
            out.iter()
                .map(|(name, r)| (*name, fleet_report_json(r)))
                .collect(),
        );
        println!("{}", j.pretty());
    }
    telemetry_finish(tele);
    Ok(())
}

/// The perf smoke behind CI's scale job: run the sync and async protocols
/// at 1 shard and at `--shards` (0 = available parallelism), assert the
/// protocol results are bit-identical, and write throughput + the
/// sharding speedup to `--bench-out` (BENCH_fleet.json).
///
/// Setup (spec parsing, fleet construction, thread spawn) and the event
/// loop are timed separately — `events_per_sec` and the speedup compare
/// event-loop time only, so the numbers measure the simulator, not the
/// constructor.
fn cmd_fleet_smoke(a: &Args) -> Result<()> {
    let t0 = std::time::Instant::now();
    let base_async = run_fleet(a, false, Some(1))?;
    let base_sync = run_fleet(a, true, Some(1))?;
    let r_async = run_fleet(a, false, None)?;
    let r_sync = run_fleet(a, true, None)?;
    let host_seconds = t0.elapsed().as_secs_f64();
    for (name, r) in [("async", &r_async), ("sync", &r_sync)] {
        print_fleet_report(name, r);
        if r.updates == 0 {
            return Err(anyhow!("fleet smoke: {name} made no updates"));
        }
    }
    // The determinism contract, enforced on every CI run: sharding may
    // only change wall-clock, never results.
    for (name, one, many) in [
        ("async", &base_async, &r_async),
        ("sync", &base_sync, &r_sync),
    ] {
        if one.updates != many.updates
            || one.wall_ms != many.wall_ms
            || one.mean_spent != many.mean_spent
            || one.messages_sent != many.messages_sent
            || one.messages_lost != many.messages_lost
        {
            return Err(anyhow!(
                "fleet smoke: {name} diverged between 1 shard and {} shards",
                many.shards
            ));
        }
    }
    let lookahead = parse_network(&a.str("network"))?
        .min_delay_ms(a.f64("model-bytes").map_err(|e| anyhow!(e))?);
    if lookahead <= 0.0 {
        eprintln!(
            "[ol4el] note: this network spec has zero lookahead (ideal/lognormal \
             latency) — sharded runs stay exact but cannot speed up; use \
             fixed:MS or uniform:LO:HI latency to measure speedups"
        );
    }
    let setup_all = base_async.setup_seconds
        + base_sync.setup_seconds
        + r_async.setup_seconds
        + r_sync.setup_seconds;
    let loop_1 = base_async.loop_seconds + base_sync.loop_seconds;
    let loop_n = r_async.loop_seconds + r_sync.loop_seconds;
    let events = r_async.events + r_sync.events;
    let evps_1 = if loop_1 > 0.0 { events as f64 / loop_1 } else { 0.0 };
    let evps_n = if loop_n > 0.0 { events as f64 / loop_n } else { 0.0 };
    let speedup = if evps_1 > 0.0 { evps_n / evps_1 } else { 0.0 };
    println!(
        "[smoke] shards={} events/sec {:.2}M (1-shard {:.2}M)  speedup {:.2}x",
        r_async.shards,
        evps_n / 1e6,
        evps_1 / 1e6,
        speedup
    );
    let j = Json::obj(vec![
        ("edges", Json::num(r_async.n_edges as f64)),
        ("shards", Json::num(r_async.shards as f64)),
        // host_seconds spans all four runs; setup + the two loop entries
        // reconcile with it (modulo teardown), so the components add up.
        ("host_seconds", Json::num(host_seconds)),
        ("setup_seconds", Json::num(setup_all)),
        ("loop_seconds_1shard", Json::num(loop_1)),
        ("loop_seconds_nshard", Json::num(loop_n)),
        ("events_per_sec", Json::num(evps_n)),
        ("events_per_sec_1shard", Json::num(evps_1)),
        ("speedup_vs_1shard", Json::num(speedup)),
        (
            "peak_queue_depth",
            Json::num(r_async.peak_queue_depth.max(r_sync.peak_queue_depth) as f64),
        ),
        // Data-parallelism provenance: the engine thread pool this run saw
        // and the edge-batch granularity (the fleet simulator steps edges
        // one at a time, so its batch is always 1).
        (
            "engine_threads",
            Json::num(ol4el::engine::pool::threads() as f64),
        ),
        ("edge_batch", Json::num(1.0)),
        ("async", fleet_report_json(&r_async)),
        ("sync", fleet_report_json(&r_sync)),
        ("async_1shard", fleet_report_json(&base_async)),
        ("sync_1shard", fleet_report_json(&base_sync)),
    ]);
    let path = a.str("bench-out");
    std::fs::write(&path, j.pretty()).map_err(|e| anyhow!("writing {path}: {e}"))?;
    eprintln!("[ol4el] wrote {path} ({host_seconds:.2}s host)");

    // The real-wire loopback measurement (net::wire): frame codec + TCP
    // transport throughput, gated > 0 in CI's net-e2e job.
    let frames = a.usize("wire-frames").map_err(|e| anyhow!(e))?.max(1);
    let wb = bench_loopback(frames).map_err(|e| anyhow!("wire bench: {e}"))?;
    println!(
        "[smoke] wire: {:.0} frames/sec  RTT mean {:.3}ms max {:.3}ms  ({} round trips of {} bytes)",
        wb.frames_per_sec, wb.mean_round_trip_ms, wb.max_round_trip_ms, wb.frames, wb.frame_bytes
    );
    let wpath = a.str("wire-bench-out");
    std::fs::write(&wpath, wb.to_json().pretty()).map_err(|e| anyhow!("writing {wpath}: {e}"))?;
    eprintln!("[ol4el] wrote {wpath}");
    append_bench_history(
        "fleet-smoke",
        &Json::obj(vec![("fleet", j), ("wire", wb.to_json())]),
    );
    Ok(())
}

/// Append one benchkit-style record to `BENCH_history.jsonl`: which bench
/// ran, when, on what machine and git revision, plus the bench's own
/// numbers — the repo's perf trajectory as one JSONL line per run.
/// Best-effort: an unwritable file is a note, never an error.
fn append_bench_history(kind: &str, payload: &Json) {
    use std::io::Write as _;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(0);
    let git = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".to_string());
    let epoch = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let rec = Json::obj(vec![
        ("bench", Json::str(kind)),
        ("epoch_secs", Json::num(epoch as f64)),
        ("git", Json::str(&git)),
        ("os", Json::str(std::env::consts::OS)),
        ("arch", Json::str(std::env::consts::ARCH)),
        ("cores", Json::num(cores as f64)),
        ("result", payload.clone()),
    ]);
    let line = format!("{rec}\n");
    let written = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_history.jsonl")
        .and_then(|mut f| f.write_all(line.as_bytes()));
    match written {
        Ok(()) => eprintln!("[ol4el] appended BENCH_history.jsonl ({kind})"),
        Err(e) => eprintln!("[ol4el] note: could not append BENCH_history.jsonl: {e}"),
    }
}

fn bench_tasks_cli() -> Cli {
    Cli::new(
        "ol4el bench-tasks",
        "per-task throughput: native local-step rate + engine-free fleet event rate",
    )
    .opt("steps", "2000", "local iterations timed per task")
    .opt(
        "fleet-edges",
        "1000",
        "fleet size of the per-task event-rate probe",
    )
    .opt("budget", "1000", "per-edge budget (ms) of the fleet probe")
    .opt(
        "threads",
        "1",
        "engine kernel threads for the batched measurement ('max' or 0 = all cores)",
    )
    .opt(
        "edge-batch",
        "1",
        "edges stepped per engine dispatch in the batched measurement",
    )
    .opt("seed", "42", "PRNG seed")
    .opt("out", "BENCH_tasks.json", "output JSON path")
}

/// The per-task throughput bench behind CI's scale-smoke job: for every
/// registered task, time `--steps` native local iterations (steps/sec)
/// and one engine-free fleet run carrying the task's config
/// (events/sec), then write BENCH_tasks.json — the perf trajectory's
/// task-diversity axis.
fn cmd_bench_tasks(argv: &[String]) -> Result<()> {
    let Some(a) = bench_tasks_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let steps = a.usize("steps").map_err(|e| anyhow!(e))?.max(1);
    let edges = a.usize("fleet-edges").map_err(|e| anyhow!(e))?.max(1);
    let budget = a.f64("budget").map_err(|e| anyhow!(e))?;
    let threads = parse_threads(&a.str("threads"))?;
    let edge_batch = a.usize("edge-batch").map_err(|e| anyhow!(e))?.max(1);
    let seed = a.u64("seed").map_err(|e| anyhow!(e))?;
    let engine = ol4el::engine::native::NativeEngine::default();

    let mut t = Table::new(
        "per-task throughput (native local steps + engine-free fleet)",
        &["task", "steps/sec", "scalar", "speedup", "events/sec"],
    );
    let mut rows = Vec::new();
    let mut resolved_threads = 1usize;
    for (name, _about) in ol4el::model::registered_tasks() {
        let spec = TaskSpec::parse(name)?;
        let learner = spec.learner();
        let mut rng = ol4el::util::rng::Rng::new(seed);
        let n = (learner.batch() * 8).max(1024);
        let ds = std::sync::Arc::new(learner.synth(n, 2.5, &mut rng));
        let mut params = learner.init_params(&ds, &mut rng);
        let mut shard = ol4el::data::partition::iid(&ds, 1, &mut rng).remove(0);
        let hyper = ol4el::edge::Hyper::default();
        let (mut xbuf, mut ybuf) = (Vec::new(), Vec::new());
        // Scalar reference: one edge, sequential kernels — the number the
        // batched measurement's speedup is reported against.
        ol4el::engine::pool::set_threads(1);
        // Warmup outside the clock.
        for _ in 0..steps.min(32) {
            shard.next_batch(learner.batch(), &mut xbuf, &mut ybuf);
            learner.local_step(&engine, &mut params, &xbuf, &ybuf, &hyper)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            shard.next_batch(learner.batch(), &mut xbuf, &mut ybuf);
            learner.local_step(&engine, &mut params, &xbuf, &ybuf, &hyper)?;
        }
        let step_secs = t0.elapsed().as_secs_f64();
        let steps_per_sec_scalar = steps as f64 / step_secs.max(1e-9);

        // Batched measurement: --edge-batch model replicas stepped per
        // engine dispatch with --threads kernel threads. At the default
        // 1/1 this equals the scalar path (same code, same numbers).
        resolved_threads = ol4el::engine::pool::set_threads(threads);
        let eb = edge_batch;
        let mut params_all: Vec<Vec<f32>> = (0..eb)
            .map(|_| learner.init_params(&ds, &mut rng))
            .collect();
        let iters = steps.div_ceil(eb).max(1);
        let (mut xall, mut yall) = (Vec::new(), Vec::new());
        let mut run_batch = |params_all: &mut Vec<Vec<f32>>,
                             shard: &mut ol4el::data::Shard,
                             iters: usize|
         -> Result<f64> {
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                xall.clear();
                yall.clear();
                for _ in 0..eb {
                    shard.next_batch(learner.batch(), &mut xbuf, &mut ybuf);
                    xall.extend_from_slice(&xbuf);
                    yall.extend_from_slice(&ybuf);
                }
                let mut refs: Vec<&mut [f32]> =
                    params_all.iter_mut().map(|p| p.as_mut_slice()).collect();
                learner.local_step_batch(&engine, &mut refs, &xall, &yall, &hyper)?;
            }
            Ok(t0.elapsed().as_secs_f64())
        };
        run_batch(&mut params_all, &mut shard, iters.min(32))?; // warmup
        let batch_secs = run_batch(&mut params_all, &mut shard, iters)?;
        ol4el::engine::pool::set_threads(1);
        let steps_per_sec = (iters * eb) as f64 / batch_secs.max(1e-9);
        let speedup = steps_per_sec / steps_per_sec_scalar.max(1e-9);

        let fleet_cfg = RunConfig {
            task: spec.clone(),
            n_edges: edges,
            hetero: 4.0,
            budget,
            eval_every: 200,
            // Engine-free probe: data is never generated; satisfy the
            // eval-split + coverage invariants at any fleet size.
            data_n: 20_000.max(edges + learner.eval_batch()),
            seed,
            ..Default::default()
        };
        let report = FleetSim::new(fleet_cfg)?.run()?;
        let events_per_sec = report.events_per_sec();

        t.row(vec![
            name.to_string(),
            f(steps_per_sec, 0),
            f(steps_per_sec_scalar, 0),
            f(speedup, 2),
            f(events_per_sec, 0),
        ]);
        rows.push(Json::obj(vec![
            ("task", Json::str(name)),
            ("steps_per_sec", Json::num(steps_per_sec)),
            ("steps_per_sec_scalar", Json::num(steps_per_sec_scalar)),
            ("speedup_vs_scalar", Json::num(speedup)),
            ("events_per_sec", Json::num(events_per_sec)),
            ("steps_timed", Json::num(steps as f64)),
            ("fleet_edges", Json::num(edges as f64)),
        ]));
    }
    print!("{}", t.render());
    let j = Json::obj(vec![
        ("seed", Json::num(seed as f64)),
        ("threads", Json::num(resolved_threads as f64)),
        ("edge_batch", Json::num(edge_batch as f64)),
        ("tasks", Json::arr(rows.into_iter())),
    ]);
    let path = a.str("out");
    std::fs::write(&path, j.pretty()).map_err(|e| anyhow!("writing {path}: {e}"))?;
    eprintln!("[ol4el] wrote {path}");
    append_bench_history("bench-tasks", &j);
    Ok(())
}

fn bench_strategies_cli() -> Cli {
    Cli::new(
        "ol4el bench-strategies",
        "per-strategy decision-loop throughput (selects/sec, updates/sec)",
    )
    .opt("iters", "200000", "select and feedback calls timed per strategy")
    .opt("edges", "64", "fleet size the strategy instance is built for")
    .opt("tau-max", "10", "arm count of the decision problem")
    .opt(
        "threads",
        "1",
        "engine kernel threads, recorded as run metadata ('max' or 0 = all \
         cores; the decision loop itself has no engine compute)",
    )
    .opt("seed", "42", "PRNG seed of the selection stream")
    .opt("out", "BENCH_strategies.json", "output JSON path")
}

/// The per-strategy decision-loop bench behind CI's scale-smoke job: for
/// every registered strategy, build one instance through the public
/// registry path (its default manner), then time `--iters` select calls
/// and `--iters` feedback calls against an ample budget — the strategy
/// layer's cost ceiling, isolated from training and transport. Writes
/// BENCH_strategies.json (gated > 0 per strategy in CI).
fn cmd_bench_strategies(argv: &[String]) -> Result<()> {
    let Some(a) = bench_strategies_cli().parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let iters = a.usize("iters").map_err(|e| anyhow!(e))?.max(1);
    let edges = a.usize("edges").map_err(|e| anyhow!(e))?.max(1);
    let tau_max = a.usize("tau-max").map_err(|e| anyhow!(e))?.max(1);
    let threads = ol4el::engine::pool::set_threads(parse_threads(&a.str("threads"))?);
    let seed = a.u64("seed").map_err(|e| anyhow!(e))?;

    let mut t = Table::new(
        "per-strategy decision-loop throughput",
        &["strategy", "selects/sec", "updates/sec"],
    );
    let mut rows = Vec::new();
    for (name, _about) in ol4el::strategy::registered_strategies() {
        let spec = StrategySpec::parse(name)?;
        let cfg = RunConfig {
            strategy: spec.clone(),
            n_edges: edges,
            hetero: 4.0,
            tau_max,
            // Ample budget: selection never retires inside the loop.
            budget: 1e12,
            data_n: RunConfig::default().data_n.max(edges + 1024),
            seed,
            ..Default::default()
        };
        cfg.validate()?;
        let mut rng = ol4el::util::rng::Rng::new(seed);
        let slowdowns = cfg
            .hetero_profile
            .slowdowns(cfg.n_edges, cfg.hetero, &mut rng);
        let mut strategy = ol4el::strategy::build(&cfg, &slowdowns)?;
        // A shared (sync) strategy always decides for index 0; per-edge
        // ones rotate across the fleet.
        let rotate = !strategy.is_sync();
        let mut sel_rng = ol4el::util::rng::Rng::new(seed ^ 0x5e1e_c7);

        // Warmup outside the clock (fills UCB-style priors).
        for k in 0..iters.min(256) {
            let e = if rotate { k % edges } else { 0 };
            if let Some(tau) = strategy.select(e, 1e12, &mut sel_rng) {
                strategy.feedback(e, tau, 0.5, tau as f64 * 40.0 + 60.0);
            }
        }
        let t0 = std::time::Instant::now();
        let mut last_tau = 1usize;
        for k in 0..iters {
            let e = if rotate { k % edges } else { 0 };
            if let Some(tau) = strategy.select(e, 1e12, &mut sel_rng) {
                last_tau = tau;
            }
        }
        let select_secs = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        for k in 0..iters {
            let e = if rotate { k % edges } else { 0 };
            let tau = 1 + (last_tau + k) % tau_max;
            strategy.feedback(e, tau, 0.5, tau as f64 * 40.0 + 60.0);
        }
        let update_secs = t1.elapsed().as_secs_f64();
        let selects_per_sec = iters as f64 / select_secs.max(1e-9);
        let updates_per_sec = iters as f64 / update_secs.max(1e-9);

        t.row(vec![
            name.to_string(),
            f(selects_per_sec, 0),
            f(updates_per_sec, 0),
        ]);
        rows.push(Json::obj(vec![
            ("strategy", Json::str(name)),
            ("selects_per_sec", Json::num(selects_per_sec)),
            ("updates_per_sec", Json::num(updates_per_sec)),
            ("iters", Json::num(iters as f64)),
            ("edges", Json::num(edges as f64)),
            ("tau_max", Json::num(tau_max as f64)),
        ]));
    }
    print!("{}", t.render());
    let j = Json::obj(vec![
        ("seed", Json::num(seed as f64)),
        ("threads", Json::num(threads as f64)),
        ("strategies", Json::arr(rows.into_iter())),
    ]);
    let path = a.str("out");
    std::fs::write(&path, j.pretty()).map_err(|e| anyhow!("writing {path}: {e}"))?;
    eprintln!("[ol4el] wrote {path}");
    append_bench_history("bench-strategies", &j);
    Ok(())
}

fn fig_cli(name: &'static str) -> Cli {
    Cli::new(name, "regenerate a paper figure")
        .opt("engine", "native", "native | pjrt")
        .opt("artifacts", "artifacts", "artifact dir for pjrt")
        .opt("seeds", "2", "seeds per cell")
        .opt("out", "results", "CSV output directory")
        .opt(
            "shards",
            "0",
            "fleet-sim worker shards for fig6 (0 = available parallelism)",
        )
        .switch("full", "full paper-sized sweep (slower)")
}

fn cmd_fig(which: &str, argv: &[String]) -> Result<()> {
    let Some(a) = fig_cli("ol4el figN").parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let opts = SweepOpts {
        quick: !a.flag("full"),
        seeds: a.u64("seeds").map_err(|e| anyhow!(e))?,
        engine: EngineKind::parse(&a.str("engine")).ok_or_else(|| anyhow!("bad --engine"))?,
        artifacts: a.str("artifacts"),
        shards: a.usize("shards").map_err(|e| anyhow!(e))?,
    };
    let t0 = std::time::Instant::now();
    let tables = match which {
        "fig3" => harness::fig3::run(&opts)?,
        "fig4" => harness::fig4::run(&opts)?,
        "fig5" => harness::fig5::run(&opts)?,
        "fig6" => harness::fig6::run(&opts)?,
        _ => unreachable!(),
    };
    let outdir = a.str("out");
    for (i, t) in tables.iter().enumerate() {
        print!("{}", t.render());
        println!();
        let path = format!("{outdir}/{which}_{i}.csv");
        t.write_csv(&path)?;
        eprintln!("[ol4el] wrote {path}");
    }
    eprintln!("[ol4el] {which} done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let cli = Cli::new("ol4el inspect-artifacts", "artifact + PJRT diagnostics")
        .opt("artifacts", "artifacts", "artifact directory");
    let Some(a) = cli.parse(argv).map_err(|e| anyhow!(e))? else {
        return Ok(());
    };
    let mut rt = ol4el::runtime::Runtime::open(a.str("artifacts"))?;
    println!("platform: {}", rt.platform_name());
    println!("devices:  {}", rt.device_count());
    println!("shapes:   {:?}", rt.manifest_shapes()?);
    for name in rt.entrypoints() {
        let bytes = rt
            .manifest
            .path(&["entrypoints", &name, "bytes"])
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        let t0 = std::time::Instant::now();
        rt.executable(&name)?;
        println!(
            "  {name:<14} {bytes:>8.0} bytes HLO   compile {:.0} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    Ok(())
}
