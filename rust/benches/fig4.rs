//! Bench: regenerate paper Figure 4 (accuracy vs consumed edge resource at
//! heterogeneity H=6; trade-off curves for all four algorithms).

mod common;

fn main() {
    let opts = common::opts_from_env();
    let t0 = std::time::Instant::now();
    let tables = ol4el::harness::fig4::run(&opts).expect("fig4 sweep");
    common::emit("fig4", &tables);
    eprintln!(
        "[bench fig4] engine={} quick={} seeds={} elapsed={:.1}s",
        opts.engine.name(),
        opts.quick,
        opts.seeds,
        t0.elapsed().as_secs_f64()
    );
}
