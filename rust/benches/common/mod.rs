//! Shared plumbing for the bench harnesses (criterion is unavailable
//! offline; these are self-timed `harness = false` benches driven by the
//! library's harness module).
//!
//! Env knobs: OL4EL_BENCH_FULL=1 for the paper-sized sweep,
//! OL4EL_BENCH_SEEDS=n, OL4EL_BENCH_ENGINE=native|pjrt.

use ol4el::harness::{EngineKind, SweepOpts};

#[allow(dead_code)]
pub fn opts_from_env() -> SweepOpts {
    let full = std::env::var("OL4EL_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let seeds = std::env::var("OL4EL_BENCH_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let engine = std::env::var("OL4EL_BENCH_ENGINE")
        .ok()
        .and_then(|v| EngineKind::parse(&v))
        .unwrap_or(EngineKind::Native);
    SweepOpts {
        quick: !full,
        seeds,
        engine,
        artifacts: artifacts_dir(),
        ..Default::default()
    }
}

#[allow(dead_code)]
pub fn artifacts_dir() -> String {
    std::env::var("OL4EL_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// Print tables and mirror them to results/.
#[allow(dead_code)]
pub fn emit(name: &str, tables: &[ol4el::util::table::Table]) {
    for (i, t) in tables.iter().enumerate() {
        print!("{}", t.render());
        println!();
        let path = format!("results/{name}_{i}.csv");
        if let Err(e) = t.write_csv(&path) {
            eprintln!("[bench] csv write failed ({path}): {e}");
        } else {
            eprintln!("[bench] wrote {path}");
        }
    }
}
