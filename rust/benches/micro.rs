//! Micro-benchmarks of the L3 hot paths (self-timed; criterion is not
//! available offline): bandit decision latency, aggregation throughput,
//! native vs PJRT step latency, async event-loop rate. These are the
//! numbers behind EXPERIMENTS.md §Perf.

mod common;

use ol4el::bandit::{kube::Kube, ucb_bv::UcbBv, BudgetedBandit};
use ol4el::coordinator::aggregate;
use ol4el::edge::Hyper;
use ol4el::engine::native::NativeEngine;
use ol4el::model::{Learner as _, ModelState, TaskSpec};
use ol4el::sim::clock::EventQueue;
use ol4el::util::rng::Rng;
use ol4el::util::table::{f, Table};

fn time_it<R>(iters: usize, mut body: impl FnMut() -> R) -> (f64, f64) {
    // Warmup.
    for _ in 0..iters.min(32) {
        std::hint::black_box(body());
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(body());
    }
    let total = t0.elapsed().as_secs_f64();
    (total / iters as f64, total)
}

fn main() {
    let mut t = Table::new(
        "micro: L3 hot paths",
        &["benchmark", "iters", "per-op", "ops/s"],
    );
    let fmt_time = |s: f64| {
        if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else if s >= 1e-6 {
            format!("{:.3} µs", s * 1e6)
        } else {
            format!("{:.1} ns", s * 1e9)
        }
    };
    let mut rng = Rng::new(0);

    // Bandit decision latency (10 arms, warm stats).
    {
        let mut b = Kube::new((1..=10).map(|t| 10.0 * t as f64 + 30.0).collect(), 0.1);
        for k in 0..10 {
            b.update(k, 0.5, b.expected_cost(k));
        }
        let iters = 200_000;
        let (per, _) = time_it(iters, || {
            let k = b.select(1e9, &mut rng).unwrap();
            b.update(k, 0.5, 40.0);
            k
        });
        t.row(vec![
            "kube select+update".into(),
            iters.to_string(),
            fmt_time(per),
            f(1.0 / per, 0),
        ]);
    }
    {
        let mut b = UcbBv::new(vec![40.0; 10]);
        for k in 0..10 {
            b.update(k, 0.5, 40.0);
        }
        let iters = 200_000;
        let (per, _) = time_it(iters, || {
            let k = b.select(1e9, &mut rng).unwrap();
            b.update(k, 0.5, 40.0);
            k
        });
        t.row(vec![
            "ucb-bv select+update".into(),
            iters.to_string(),
            fmt_time(per),
            f(1.0 / per, 0),
        ]);
    }

    // Aggregation throughput: weighted average of 100 SVM models (480 f32).
    {
        let models: Vec<ModelState> = (0..100)
            .map(|i| ModelState::new(vec![i as f32; 480]))
            .collect();
        let iters = 20_000;
        let (per, _) = time_it(iters, || {
            let pairs: Vec<(&ModelState, f64)> = models.iter().map(|m| (m, 1.0)).collect();
            aggregate::weighted_average(&pairs)
        });
        let bytes = 100.0 * 480.0 * 4.0;
        t.row(vec![
            "aggregate 100x480 f32".into(),
            iters.to_string(),
            fmt_time(per),
            format!("{:.2} GB/s", bytes / per / 1e9),
        ]);
    }

    // Async event queue throughput.
    {
        let iters = 50_000usize;
        let (per, _) = time_it(100, || {
            let mut q = EventQueue::new();
            for i in 0..iters {
                q.push(i as f64, i % 64);
            }
            let mut n = 0;
            while q.pop().is_some() {
                n += 1;
            }
            n
        });
        let per_event = per / iters as f64;
        t.row(vec![
            "event queue push+pop".into(),
            (100 * iters).to_string(),
            fmt_time(per_event),
            format!("{:.1} M events/s", 1.0 / per_event / 1e6),
        ]);
    }

    // Native local-step latencies per registered task (the simulator's
    // inner loop now dispatches through the Learner plugin API).
    {
        let eng = NativeEngine::default();
        let hyper = Hyper::default();
        for (name, _) in ol4el::model::registered_tasks() {
            let learner = TaskSpec::parse(name).expect("registered").learner();
            let mut rng = Rng::new(0);
            let ds = learner.synth(4096, 2.5, &mut rng);
            let mut params = learner.init_params(&ds, &mut rng);
            let n = learner.batch();
            let x = ds.x[..n * ds.d].to_vec();
            let y = ds.y[..n].to_vec();
            let iters = 5_000;
            let (per, _) = time_it(iters, || {
                learner
                    .local_step(&eng, &mut params, &x, &y, &hyper)
                    .unwrap()
                    .signal
            });
            t.row(vec![
                format!("native {name} step"),
                iters.to_string(),
                fmt_time(per),
                f(1.0 / per, 0),
            ]);
        }
    }

    // PJRT fused-kernel latency, if artifacts are present (the full
    // L1+L2 path; tasks without artifacts run their portable path).
    match ol4el::engine::pjrt::PjrtEngine::open(common::artifacts_dir()) {
        Ok(eng) => {
            eng.warmup().expect("warmup");
            let hyper = Hyper::default();
            for name in ["svm", "kmeans"] {
                let learner = TaskSpec::parse(name).expect("registered").learner();
                let mut rng = Rng::new(0);
                let ds = learner.synth(4096, 2.5, &mut rng);
                let mut params = learner.init_params(&ds, &mut rng);
                let n = learner.batch();
                let x = ds.x[..n * ds.d].to_vec();
                let y = ds.y[..n].to_vec();
                let iters = 200;
                let (per, _) = time_it(iters, || {
                    learner
                        .local_step(&eng, &mut params, &x, &y, &hyper)
                        .unwrap()
                        .signal
                });
                t.row(vec![
                    format!("pjrt {name} step"),
                    iters.to_string(),
                    fmt_time(per),
                    f(1.0 / per, 0),
                ]);
            }
        }
        Err(e) => {
            eprintln!("[bench micro] pjrt rows skipped: {e}");
        }
    }

    common::emit("micro", &[t]);
}
