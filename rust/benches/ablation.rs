//! Ablation benches for the design choices DESIGN.md §6 calls out:
//!   A1. bandit policy (kube / ucb-bv / ucb1 / eps-greedy) under fixed costs
//!   A2. fixed-vs-variable cost algorithm mismatch (kube under variable
//!       costs vs ucb-bv under variable costs — §IV-B.2's motivation)
//!   A3. utility definition (eval-gain vs param-delta)
//!   A4. async staleness decay exponent
//!   A5. IID vs label-skew sharding

mod common;

use ol4el::config::{PartitionKind, RunConfig};
use ol4el::coordinator::utility::UtilityKind;
use ol4el::harness::run_seeds;
use ol4el::model::TaskSpec;
use ol4el::sim::cost::CostMode;
use ol4el::strategy::StrategySpec;
use ol4el::util::table::{f, Table};

fn base(opts: &ol4el::harness::SweepOpts) -> RunConfig {
    // Paper regime (label-skew for SVM) at a budget inside the rising part
    // of the learning curve, so ablated knobs actually move the metric.
    RunConfig {
        task: TaskSpec::svm(),
        strategy: StrategySpec::ol4el_async(),
        n_edges: 3,
        hetero: 6.0,
        budget: 3500.0,
        data_n: opts.data_n(),
        ..Default::default()
    }
    .with_paper_utility()
}

fn main() {
    let opts = common::opts_from_env();
    let engine = ol4el::harness::build_engine(opts.engine, &common::artifacts_dir())
        .expect("engine");
    let engine = engine.as_ref();
    let seeds = opts.seed_list();
    let t0 = std::time::Instant::now();
    let mut tables = Vec::new();

    // A1: bandit policy under fixed costs.
    {
        let mut t = Table::new(
            "A1: bandit policy (fixed costs, H=6, async)",
            &["bandit", "accuracy", "updates"],
        );
        for bandit in ["kube", "ucb-bv", "ucb1", "eps-greedy", "thompson"] {
            let mut cfg = base(&opts);
            cfg.strategy =
                StrategySpec::parse(&format!("ol4el:bandit={bandit}")).expect("spec");
            let agg = run_seeds(&cfg, engine, &seeds).expect("run");
            t.row(vec![
                bandit.into(),
                f(agg.metric.mean(), 4),
                f(agg.updates.mean(), 0),
            ]);
        }
        tables.push(t);
    }

    // A2: cost-model mismatch — KUBE (assumes fixed) vs UCB-BV (learns
    // costs) when costs are actually variable.
    {
        let mut t = Table::new(
            "A2: variable-cost robustness (cv=0.4)",
            &["bandit", "accuracy", "updates"],
        );
        for bandit in ["kube", "ucb-bv"] {
            let mut cfg = base(&opts);
            cfg.cost.mode = CostMode::Variable { cv: 0.4 };
            cfg.strategy =
                StrategySpec::parse(&format!("ol4el:bandit={bandit}")).expect("spec");
            let agg = run_seeds(&cfg, engine, &seeds).expect("run");
            t.row(vec![
                bandit.into(),
                f(agg.metric.mean(), 4),
                f(agg.updates.mean(), 0),
            ]);
        }
        tables.push(t);
    }

    // A3: utility definition, both tasks.
    {
        let mut t = Table::new(
            "A3: learning-utility definition",
            &["task", "utility", "metric"],
        );
        for task in [TaskSpec::svm(), TaskSpec::kmeans()] {
            for util in [UtilityKind::EvalGain, UtilityKind::ParamDelta] {
                let mut cfg = base(&opts);
                cfg.task = task.clone();
                cfg.utility = util;
                let agg = run_seeds(&cfg, engine, &seeds).expect("run");
                t.row(vec![
                    task.name().into(),
                    util.name().into(),
                    f(agg.metric.mean(), 4),
                ]);
            }
        }
        tables.push(t);
    }

    // A4: staleness decay exponent (async merge discounting).
    {
        let mut t = Table::new(
            "A4: async staleness decay (H=10)",
            &["decay", "accuracy"],
        );
        for decay in [0.0, 0.25, 0.5, 1.0, 2.0] {
            let mut cfg = base(&opts);
            cfg.hetero = 10.0;
            cfg.staleness_decay = decay;
            let agg = run_seeds(&cfg, engine, &seeds).expect("run");
            t.row(vec![f(decay, 2), f(agg.metric.mean(), 4)]);
        }
        tables.push(t);
    }

    // A5: sharding regime.
    {
        let mut t = Table::new(
            "A5: data partitioning across edges",
            &["partition", "accuracy"],
        );
        for part in [
            PartitionKind::Iid,
            PartitionKind::LabelSkew { alpha: 1.0 },
            PartitionKind::LabelSkew { alpha: 0.1 },
        ] {
            let mut cfg = base(&opts);
            cfg.partition = part;
            let agg = run_seeds(&cfg, engine, &seeds).expect("run");
            t.row(vec![part.name(), f(agg.metric.mean(), 4)]);
        }
        tables.push(t);
    }

    common::emit("ablation", &tables);
    eprintln!(
        "[bench ablation] elapsed={:.1}s",
        t0.elapsed().as_secs_f64()
    );
}
