//! Bench: regenerate paper Figure 5 (accuracy vs number of edges, 3..100,
//! under H in {1,5,10,15}; OL4EL-async + OL4EL-sync; both tasks).

mod common;

fn main() {
    let opts = common::opts_from_env();
    let t0 = std::time::Instant::now();
    let tables = ol4el::harness::fig5::run(&opts).expect("fig5 sweep");
    common::emit("fig5", &tables);
    eprintln!(
        "[bench fig5] engine={} quick={} seeds={} elapsed={:.1}s",
        opts.engine.name(),
        opts.quick,
        opts.seeds,
        t0.elapsed().as_secs_f64()
    );
}
