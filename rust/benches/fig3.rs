//! Bench: regenerate paper Figure 3 (accuracy vs heterogeneity, 3 edges,
//! 5000 ms budget; K-means F1 + SVM accuracy; 4 algorithms).
//! Run `OL4EL_BENCH_FULL=1 cargo bench --bench fig3` for the paper-sized grid.

mod common;

fn main() {
    let opts = common::opts_from_env();
    let t0 = std::time::Instant::now();
    let tables = ol4el::harness::fig3::run(&opts).expect("fig3 sweep");
    common::emit("fig3", &tables);
    eprintln!(
        "[bench fig3] engine={} quick={} seeds={} elapsed={:.1}s",
        opts.engine.name(),
        opts.quick,
        opts.seeds,
        t0.elapsed().as_secs_f64()
    );
}
