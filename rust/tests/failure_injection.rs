//! Failure-injection and robustness tests: edge crashes, degenerate
//! configurations, and adversarial parameterizations must never hang,
//! panic, or corrupt the budget ledger.

use ol4el::config::RunConfig;
use ol4el::coordinator;
use ol4el::engine::native::NativeEngine;
use ol4el::model::TaskSpec;
use ol4el::sim::cost::CostMode;
use ol4el::strategy::StrategySpec;

fn base() -> RunConfig {
    RunConfig {
        task: TaskSpec::svm(),
        n_edges: 4,
        hetero: 4.0,
        budget: 1500.0,
        data_n: 3000,
        seed: 5,
        ..Default::default()
    }
    .with_paper_utility()
}

#[test]
fn async_run_survives_edge_crashes() {
    let engine = NativeEngine::default();
    for rate in [0.02, 0.1, 0.5] {
        let mut c = base();
        c.failure_rate = rate;
        let r = coordinator::run(&c, &engine).unwrap();
        assert_eq!(r.retired_edges, 4, "rate {rate}: all edges must terminate");
        // Crashes cut updates relative to the failure-free run.
        let r0 = coordinator::run(&base(), &engine).unwrap();
        assert!(
            r.total_updates <= r0.total_updates,
            "rate {rate}: {} > {}",
            r.total_updates,
            r0.total_updates
        );
    }
}

#[test]
fn certain_crash_still_terminates_cleanly() {
    let engine = NativeEngine::default();
    let mut c = base();
    c.failure_rate = 1.0; // every edge dies before its first round
    let r = coordinator::run(&c, &engine).unwrap();
    assert_eq!(r.total_updates, 0);
    assert_eq!(r.retired_edges, 4);
    assert_eq!(r.mean_spent, 0.0);
}

#[test]
fn crashes_degrade_but_do_not_destroy_accuracy() {
    let engine = NativeEngine::default();
    let mut healthy = base();
    healthy.budget = 4000.0;
    let mut flaky = healthy.clone();
    flaky.failure_rate = 0.05;
    let r_h = coordinator::run(&healthy, &engine).unwrap();
    let r_f = coordinator::run(&flaky, &engine).unwrap();
    assert!(r_f.final_metric > 0.25, "flaky run collapsed: {}", r_f.final_metric);
    assert!(
        r_f.final_metric <= r_h.final_metric + 0.05,
        "failures should not make things better: {} vs {}",
        r_f.final_metric,
        r_h.final_metric
    );
}

#[test]
fn extreme_heterogeneity_terminates() {
    let engine = NativeEngine::default();
    let mut c = base();
    c.hetero = 100.0; // slowest edge 100x slower: one tau=1 round ~4060ms
    c.budget = 5000.0;
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.total_updates > 0, "fast edges must still update");
}

#[test]
fn tau_max_one_degenerates_to_constant_policy() {
    let engine = NativeEngine::default();
    let mut c = base();
    c.tau_max = 1;
    let r = coordinator::run(&c, &engine).unwrap();
    assert_eq!(r.tau_histogram.len(), 1);
    assert!(r.total_updates > 0);
}

#[test]
fn huge_tau_max_with_tiny_budget_only_uses_feasible_arms() {
    let engine = NativeEngine::default();
    let mut c = base();
    c.tau_max = 50;
    c.budget = 300.0; // arm tau=50 at slowdown 4 costs ~8060ms: infeasible
    let r = coordinator::run(&c, &engine).unwrap();
    // All pulls must sit in the affordable prefix of the arm set.
    let max_pulled = r
        .tau_histogram
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(i, _)| i + 1)
        .max()
        .unwrap_or(0);
    let affordable = (1..=50)
        .filter(|&t| c.cost.nominal_arm_cost(t, 1.0) <= 300.0)
        .max()
        .unwrap_or(0);
    assert!(
        max_pulled <= affordable,
        "pulled tau={max_pulled}, affordable max tau={affordable}"
    );
}

#[test]
fn all_bandits_run_all_manners() {
    let engine = NativeEngine::default();
    for bandit in ["kube", "ucb-bv", "ucb1", "eps-greedy", "thompson"] {
        for mode in ["sync", "async"] {
            let mut c = base();
            c.strategy =
                StrategySpec::parse(&format!("ol4el:bandit={bandit}:mode={mode}")).unwrap();
            c.budget = 1000.0;
            let r = coordinator::run(&c, &engine).unwrap();
            assert!(r.total_updates > 0, "{bandit}/{mode} produced no updates");
        }
    }
}

#[test]
fn variable_costs_with_huge_cv_never_hang() {
    let engine = NativeEngine::default();
    let mut c = base();
    c.cost.mode = CostMode::Variable { cv: 2.0 }; // wild cost noise
    let r = coordinator::run(&c, &engine).unwrap();
    assert_eq!(r.retired_edges, 4);
}

#[test]
fn checkpointed_failure_injection_resumes_bit_identically() {
    // Failure draws come from checkpointed RNG streams, so even a run
    // that kills edges at random is restart-equal: periodic snapshots
    // don't perturb it, and resuming the last snapshot reproduces the
    // uninterrupted run's final scalars bit for bit.
    use ol4el::coordinator::{checkpoint, Session};
    let engine = NativeEngine::default();
    let mut c = base();
    c.failure_rate = 0.1;
    let r0 = coordinator::run(&c, &engine).unwrap();

    let dir = std::env::temp_dir().join(format!("ol4el-fail-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt.json");
    let mut s = Session::new(&c, &engine).unwrap();
    s.set_checkpoint(1, &path);
    let r1 = s.run().unwrap();
    assert_eq!(r0.final_metric.to_bits(), r1.final_metric.to_bits());
    assert_eq!(r0.total_updates, r1.total_updates);
    assert_eq!(r0.wall_ms.to_bits(), r1.wall_ms.to_bits());
    assert_eq!(r0.retired_edges, r1.retired_edges);

    let doc = checkpoint::load(&path).unwrap();
    let r2 = Session::resume(&doc, &engine).unwrap().run().unwrap();
    assert_eq!(r0.final_metric.to_bits(), r2.final_metric.to_bits());
    assert_eq!(r0.total_updates, r2.total_updates);
    assert_eq!(r0.wall_ms.to_bits(), r2.wall_ms.to_bits());
    assert_eq!(r0.mean_spent.to_bits(), r2.mean_spent.to_bits());
    assert_eq!(r0.tau_histogram, r2.tau_histogram);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn churned_manners_refuse_to_checkpoint() {
    // The simulated network/churn manners have not opted into
    // snapshot(): arming checkpoints under them must be a loud, typed
    // error at the first boundary — never a silently-wrong resume.
    use ol4el::coordinator::Session;
    use ol4el::net::ChurnSpec;
    let engine = NativeEngine::default();
    let mut c = base();
    c.churn = ChurnSpec::parse("poisson:0.05").unwrap();
    let dir = std::env::temp_dir().join(format!("ol4el-churn-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut s = Session::new(&c, &engine).unwrap();
    s.set_checkpoint(1, dir.join("nope.json"));
    let err = s.run().unwrap_err().to_string();
    assert!(
        err.contains("snapshot"),
        "expected a manner-opt-out error, got: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn threaded_deploy_with_failures_is_not_supported_but_sim_is() {
    // Document the contract: failure injection lives in the simulator
    // path; the threaded deploy runs crash-free (its failure mode is a
    // real thread panic, covered by run_threaded's join handling).
    let engine = NativeEngine::default();
    let mut c = base();
    c.failure_rate = 0.2;
    let r = coordinator::run(&c, &engine).unwrap();
    assert_eq!(r.retired_edges, 4);
}
