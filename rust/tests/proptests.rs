//! Property-based tests (in-repo testkit; proptest is unavailable offline)
//! over the coordinator's invariants: budget accounting, arm feasibility,
//! aggregation weights, event ordering, metric ranges.

use ol4el::bandit::{kube::Kube, ucb_bv::UcbBv, BudgetedBandit};
use ol4el::config::{PartitionKind, RunConfig};
use ol4el::coordinator::{self, aggregate};
use ol4el::engine::native::NativeEngine;
use ol4el::metrics;
use ol4el::model::{ModelState, TaskSpec};
use ol4el::prop_assert;
use ol4el::sim::clock::EventQueue;
use ol4el::sim::hetero::{realized_ratio, HeteroProfile};
use ol4el::strategy::StrategySpec;
use ol4el::testkit::property;
use ol4el::util::rng::Rng;

#[test]
fn prop_bandit_never_selects_unaffordable_arm() {
    property(
        0xB1,
        60,
        |g| {
            let n_arms = g.int(1, 8);
            let costs: Vec<f64> = (0..n_arms).map(|_| g.float(1.0, 100.0)).collect();
            let budget = g.float(0.0, 300.0);
            let pulls = g.int(1, 30);
            (costs, budget, pulls)
        },
        |(costs, budget, pulls)| {
            let mut rng = Rng::new(7);
            let mut b = Kube::new(costs.clone(), 0.2);
            for _ in 0..*pulls {
                match b.select(*budget, &mut rng) {
                    Some(k) => {
                        prop_assert!(
                            costs[k] <= *budget,
                            "selected arm {k} costing {} with budget {budget}",
                            costs[k]
                        );
                        b.update(k, 0.5, costs[k]);
                    }
                    None => {
                        let cheapest = costs.iter().cloned().fold(f64::MAX, f64::min);
                        prop_assert!(
                            cheapest > *budget,
                            "returned None but arm costing {cheapest} was affordable"
                        );
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ucb_bv_expected_costs_track_observations() {
    property(
        0xB2,
        40,
        |g| {
            let n_arms = g.int(1, 6);
            let true_costs: Vec<f64> = (0..n_arms).map(|_| g.float(5.0, 50.0)).collect();
            (true_costs, g.int(20, 200))
        },
        |(true_costs, rounds)| {
            let mut rng = Rng::new(11);
            let mut b = UcbBv::new(vec![10.0; true_costs.len()]);
            for _ in 0..*rounds {
                if let Some(k) = b.select(1e9, &mut rng) {
                    let c = true_costs[k] * (0.8 + 0.4 * rng.f64());
                    b.update(k, 0.5, c);
                }
            }
            for k in 0..true_costs.len() {
                if b.stats(k).pulls >= 10 {
                    let est = b.expected_cost(k);
                    prop_assert!(
                        (est - true_costs[k]).abs() / true_costs[k] < 0.35,
                        "arm {k}: est {est:.1} vs true {:.1}",
                        true_costs[k]
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_weighted_average_within_convex_hull() {
    property(
        0xA1,
        80,
        |g| {
            let n = g.int(1, 10);
            let len = g.int(1, 32);
            let models: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..len).map(|_| g.float(-10.0, 10.0)).collect())
                .collect();
            let weights: Vec<f64> = (0..n).map(|_| g.float(0.01, 5.0)).collect();
            (models, weights)
        },
        |(models, weights)| {
            let states: Vec<ModelState> = models
                .iter()
                .map(|p| ModelState::new(p.iter().map(|&v| v as f32).collect()))
                .collect();
            let pairs: Vec<(&ModelState, f64)> =
                states.iter().zip(weights.iter().copied()).collect();
            let avg = aggregate::weighted_average(&pairs);
            for j in 0..models[0].len() {
                let lo = models.iter().map(|m| m[j]).fold(f64::MAX, f64::min);
                let hi = models.iter().map(|m| m[j]).fold(f64::MIN, f64::max);
                let v = avg.params[j] as f64;
                prop_assert!(
                    v >= lo - 1e-3 && v <= hi + 1e-3,
                    "coord {j}: {v} outside [{lo}, {hi}]"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_pops_sorted() {
    property(
        0xE1,
        60,
        |g| {
            let n = g.int(1, 200);
            g.vec(n, |g| g.float(0.0, 1000.0))
        },
        |times| {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.push(t, i);
            }
            let mut last = -1.0f64;
            let mut count = 0;
            while let Some(ev) = q.pop() {
                prop_assert!(ev.time >= last, "out of order: {} after {last}", ev.time);
                last = ev.time;
                count += 1;
            }
            prop_assert!(count == times.len(), "lost events: {count}/{}", times.len());
            Ok(())
        },
    );
}

#[test]
fn prop_hetero_profiles_realize_requested_ratio() {
    property(
        0x41,
        60,
        |g| {
            let n = g.int(2, 50);
            let h = g.float(1.0, 20.0);
            let profile = *g.choice(&[HeteroProfile::Linear, HeteroProfile::Random]);
            (n, h, profile)
        },
        |&(n, h, profile)| {
            let mut rng = Rng::new(5);
            let s = profile.slowdowns(n, h, &mut rng);
            prop_assert!(s.len() == n, "wrong count");
            prop_assert!(
                (realized_ratio(&s) - h).abs() < 1e-6,
                "ratio {} != {h}",
                realized_ratio(&s)
            );
            prop_assert!(
                s.iter().all(|&v| v >= 1.0 - 1e-12 && v <= h + 1e-9),
                "slowdown out of [1, H]"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_clustering_f1_permutation_invariant_and_bounded() {
    property(
        0xF1,
        60,
        |g| {
            let n = g.int(6, 200);
            let k = g.int(2, 4);
            let truth: Vec<i32> = (0..n).map(|_| g.int(0, k - 1) as i32).collect();
            let assign: Vec<i32> = (0..n).map(|_| g.int(0, k - 1) as i32).collect();
            let shift = g.int(0, k - 1);
            (truth, assign, k, shift)
        },
        |(truth, assign, k, shift)| {
            let f1 = metrics::clustering_f1(assign, truth, *k);
            prop_assert!((0.0..=1.0).contains(&f1), "f1 {f1} out of range");
            // Relabeling clusters must not change the matched score.
            let relabeled: Vec<i32> = assign
                .iter()
                .map(|&a| ((a as usize + shift) % k) as i32)
                .collect();
            let f1b = metrics::clustering_f1(&relabeled, truth, *k);
            prop_assert!((f1 - f1b).abs() < 1e-9, "relabel changed f1: {f1} vs {f1b}");
            Ok(())
        },
    );
}

#[test]
fn prop_runs_respect_budget_ledger() {
    // For random small configs, no edge's spend may exceed budget by more
    // than one maximal round (the in-flight round that exhausts it).
    property(
        0xC1,
        8,
        |g| {
            let strategy = g
                .choice(&[
                    StrategySpec::ol4el_sync(),
                    StrategySpec::ol4el_async(),
                    StrategySpec::ac_sync(),
                    StrategySpec::fixed_i(),
                ])
                .clone();
            let task = g
                .choice(&[
                    TaskSpec::svm(),
                    TaskSpec::kmeans(),
                    TaskSpec::logreg(),
                    TaskSpec::gmm(),
                ])
                .clone();
            let hetero = g.float(1.0, 8.0);
            let budget = g.float(300.0, 1200.0);
            let n_edges = g.int(2, 4);
            (strategy, task, hetero, budget, n_edges)
        },
        |(strategy, task, hetero, budget, n_edges)| {
            let (hetero, budget, n_edges) = (*hetero, *budget, *n_edges);
            let engine = NativeEngine::default();
            let cfg = RunConfig {
                task: task.clone(),
                strategy: strategy.clone(),
                n_edges,
                hetero,
                budget,
                data_n: 3000,
                seed: 17,
                ..Default::default()
            };
            let r = coordinator::run(&cfg, &engine).map_err(|e| e.to_string())?;
            let max_round =
                cfg.cost.nominal_arm_cost(cfg.tau_max, hetero) * (1.0 + cfg.ac_overhead) * 2.0;
            prop_assert!(
                r.mean_spent <= budget + max_round,
                "{strategy}: mean spent {} vs budget {budget}",
                r.mean_spent
            );
            prop_assert!(
                (0.0..=1.0).contains(&r.final_metric),
                "metric {} out of range",
                r.final_metric
            );
            Ok(())
        },
    );
}

#[test]
fn prop_partitions_are_exact_covers() {
    use ol4el::data::synth::TrafficLike;
    use std::sync::Arc;
    property(
        0xD1,
        30,
        |g| {
            let n_rows = g.int(50, 2000);
            let n_edges = g.int(1, 20.min(n_rows / 3));
            let alpha = g.float(0.05, 5.0);
            let skew = g.bool();
            (n_rows, n_edges.max(1), alpha, skew)
        },
        |&(n_rows, n_edges, alpha, skew)| {
            let mut rng = Rng::new(23);
            let ds = Arc::new(
                TrafficLike {
                    n: n_rows,
                    ..Default::default()
                }
                .generate(&mut rng),
            );
            let shards = if skew {
                ol4el::data::partition::label_skew(&ds, n_edges, alpha, &mut rng)
            } else {
                ol4el::data::partition::iid(&ds, n_edges, &mut rng)
            };
            let mut seen: Vec<usize> =
                shards.iter().flat_map(|s| s.indices.clone()).collect();
            seen.sort_unstable();
            prop_assert!(seen.len() == n_rows, "covered {} of {n_rows}", seen.len());
            prop_assert!(
                seen == (0..n_rows).collect::<Vec<_>>(),
                "partition is not an exact cover"
            );
            prop_assert!(shards.iter().all(|s| !s.is_empty()), "empty shard");
            Ok(())
        },
    );
}

#[test]
fn prop_label_skew_respects_partition_kind_parse() {
    property(
        0xD2,
        40,
        |g| g.float(0.01, 10.0),
        |&alpha| {
            let s = format!("skew:{alpha}");
            match PartitionKind::parse(&s) {
                Some(PartitionKind::LabelSkew { alpha: a }) => {
                    prop_assert!((a - alpha).abs() < 1e-9, "parsed {a} != {alpha}");
                    Ok(())
                }
                other => Err(format!("parse '{s}' gave {other:?}")),
            }
        },
    );
}
