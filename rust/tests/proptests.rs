//! Property-based tests (in-repo testkit; proptest is unavailable offline)
//! over the coordinator's invariants: budget accounting, arm feasibility,
//! aggregation weights, event ordering, metric ranges.

use ol4el::bandit::{self, kube::Kube, ucb_bv::UcbBv, BanditSpec, BudgetedBandit};
use ol4el::config::{PartitionKind, RunConfig};
use ol4el::coordinator::{self, aggregate};
use ol4el::engine::native::NativeEngine;
use ol4el::metrics;
use ol4el::model::{ModelState, TaskSpec};
use ol4el::prop_assert;
use ol4el::sim::clock::EventQueue;
use ol4el::sim::hetero::{realized_ratio, HeteroProfile};
use ol4el::strategy::{self, Strategy, StrategySpec};
use ol4el::testkit::property;
use ol4el::util::rng::Rng;

#[test]
fn prop_bandit_never_selects_unaffordable_arm() {
    property(
        0xB1,
        60,
        |g| {
            let n_arms = g.int(1, 8);
            let costs: Vec<f64> = (0..n_arms).map(|_| g.float(1.0, 100.0)).collect();
            let budget = g.float(0.0, 300.0);
            let pulls = g.int(1, 30);
            (costs, budget, pulls)
        },
        |(costs, budget, pulls)| {
            let mut rng = Rng::new(7);
            let mut b = Kube::new(costs.clone(), 0.2);
            for _ in 0..*pulls {
                match b.select(*budget, &mut rng) {
                    Some(k) => {
                        prop_assert!(
                            costs[k] <= *budget,
                            "selected arm {k} costing {} with budget {budget}",
                            costs[k]
                        );
                        b.update(k, 0.5, costs[k]);
                    }
                    None => {
                        let cheapest = costs.iter().cloned().fold(f64::MAX, f64::min);
                        prop_assert!(
                            cheapest > *budget,
                            "returned None but arm costing {cheapest} was affordable"
                        );
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ucb_bv_expected_costs_track_observations() {
    property(
        0xB2,
        40,
        |g| {
            let n_arms = g.int(1, 6);
            let true_costs: Vec<f64> = (0..n_arms).map(|_| g.float(5.0, 50.0)).collect();
            (true_costs, g.int(20, 200))
        },
        |(true_costs, rounds)| {
            let mut rng = Rng::new(11);
            let mut b = UcbBv::new(vec![10.0; true_costs.len()]);
            for _ in 0..*rounds {
                if let Some(k) = b.select(1e9, &mut rng) {
                    let c = true_costs[k] * (0.8 + 0.4 * rng.f64());
                    b.update(k, 0.5, c);
                }
            }
            for k in 0..true_costs.len() {
                if b.stats(k).pulls >= 10 {
                    let est = b.expected_cost(k);
                    prop_assert!(
                        (est - true_costs[k]).abs() / true_costs[k] < 0.35,
                        "arm {k}: est {est:.1} vs true {:.1}",
                        true_costs[k]
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_weighted_average_within_convex_hull() {
    property(
        0xA1,
        80,
        |g| {
            let n = g.int(1, 10);
            let len = g.int(1, 32);
            let models: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..len).map(|_| g.float(-10.0, 10.0)).collect())
                .collect();
            let weights: Vec<f64> = (0..n).map(|_| g.float(0.01, 5.0)).collect();
            (models, weights)
        },
        |(models, weights)| {
            let states: Vec<ModelState> = models
                .iter()
                .map(|p| ModelState::new(p.iter().map(|&v| v as f32).collect()))
                .collect();
            let pairs: Vec<(&ModelState, f64)> =
                states.iter().zip(weights.iter().copied()).collect();
            let avg = aggregate::weighted_average(&pairs);
            for j in 0..models[0].len() {
                let lo = models.iter().map(|m| m[j]).fold(f64::MAX, f64::min);
                let hi = models.iter().map(|m| m[j]).fold(f64::MIN, f64::max);
                let v = avg.params[j] as f64;
                prop_assert!(
                    v >= lo - 1e-3 && v <= hi + 1e-3,
                    "coord {j}: {v} outside [{lo}, {hi}]"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_pops_sorted() {
    property(
        0xE1,
        60,
        |g| {
            let n = g.int(1, 200);
            g.vec(n, |g| g.float(0.0, 1000.0))
        },
        |times| {
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.push(t, i);
            }
            let mut last = -1.0f64;
            let mut count = 0;
            while let Some(ev) = q.pop() {
                prop_assert!(ev.time >= last, "out of order: {} after {last}", ev.time);
                last = ev.time;
                count += 1;
            }
            prop_assert!(count == times.len(), "lost events: {count}/{}", times.len());
            Ok(())
        },
    );
}

#[test]
fn prop_hetero_profiles_realize_requested_ratio() {
    property(
        0x41,
        60,
        |g| {
            let n = g.int(2, 50);
            let h = g.float(1.0, 20.0);
            let profile = *g.choice(&[HeteroProfile::Linear, HeteroProfile::Random]);
            (n, h, profile)
        },
        |&(n, h, profile)| {
            let mut rng = Rng::new(5);
            let s = profile.slowdowns(n, h, &mut rng);
            prop_assert!(s.len() == n, "wrong count");
            prop_assert!(
                (realized_ratio(&s) - h).abs() < 1e-6,
                "ratio {} != {h}",
                realized_ratio(&s)
            );
            prop_assert!(
                s.iter().all(|&v| v >= 1.0 - 1e-12 && v <= h + 1e-9),
                "slowdown out of [1, H]"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_clustering_f1_permutation_invariant_and_bounded() {
    property(
        0xF1,
        60,
        |g| {
            let n = g.int(6, 200);
            let k = g.int(2, 4);
            let truth: Vec<i32> = (0..n).map(|_| g.int(0, k - 1) as i32).collect();
            let assign: Vec<i32> = (0..n).map(|_| g.int(0, k - 1) as i32).collect();
            let shift = g.int(0, k - 1);
            (truth, assign, k, shift)
        },
        |(truth, assign, k, shift)| {
            let f1 = metrics::clustering_f1(assign, truth, *k);
            prop_assert!((0.0..=1.0).contains(&f1), "f1 {f1} out of range");
            // Relabeling clusters must not change the matched score.
            let relabeled: Vec<i32> = assign
                .iter()
                .map(|&a| ((a as usize + shift) % k) as i32)
                .collect();
            let f1b = metrics::clustering_f1(&relabeled, truth, *k);
            prop_assert!((f1 - f1b).abs() < 1e-9, "relabel changed f1: {f1} vs {f1b}");
            Ok(())
        },
    );
}

#[test]
fn prop_runs_respect_budget_ledger() {
    // For random small configs, no edge's spend may exceed budget by more
    // than one maximal round (the in-flight round that exhausts it).
    property(
        0xC1,
        8,
        |g| {
            let strategy = g
                .choice(&[
                    StrategySpec::ol4el_sync(),
                    StrategySpec::ol4el_async(),
                    StrategySpec::ac_sync(),
                    StrategySpec::fixed_i(),
                ])
                .clone();
            let task = g
                .choice(&[
                    TaskSpec::svm(),
                    TaskSpec::kmeans(),
                    TaskSpec::logreg(),
                    TaskSpec::gmm(),
                ])
                .clone();
            let hetero = g.float(1.0, 8.0);
            let budget = g.float(300.0, 1200.0);
            let n_edges = g.int(2, 4);
            (strategy, task, hetero, budget, n_edges)
        },
        |(strategy, task, hetero, budget, n_edges)| {
            let (hetero, budget, n_edges) = (*hetero, *budget, *n_edges);
            let engine = NativeEngine::default();
            let cfg = RunConfig {
                task: task.clone(),
                strategy: strategy.clone(),
                n_edges,
                hetero,
                budget,
                data_n: 3000,
                seed: 17,
                ..Default::default()
            };
            let r = coordinator::run(&cfg, &engine).map_err(|e| e.to_string())?;
            let max_round =
                cfg.cost.nominal_arm_cost(cfg.tau_max, hetero) * (1.0 + cfg.ac_overhead) * 2.0;
            prop_assert!(
                r.mean_spent <= budget + max_round,
                "{strategy}: mean spent {} vs budget {budget}",
                r.mean_spent
            );
            prop_assert!(
                (0.0..=1.0).contains(&r.final_metric),
                "metric {} out of range",
                r.final_metric
            );
            Ok(())
        },
    );
}

#[test]
fn prop_partitions_are_exact_covers() {
    use ol4el::data::synth::TrafficLike;
    use std::sync::Arc;
    property(
        0xD1,
        30,
        |g| {
            let n_rows = g.int(50, 2000);
            let n_edges = g.int(1, 20.min(n_rows / 3));
            let alpha = g.float(0.05, 5.0);
            let skew = g.bool();
            (n_rows, n_edges.max(1), alpha, skew)
        },
        |&(n_rows, n_edges, alpha, skew)| {
            let mut rng = Rng::new(23);
            let ds = Arc::new(
                TrafficLike {
                    n: n_rows,
                    ..Default::default()
                }
                .generate(&mut rng),
            );
            let shards = if skew {
                ol4el::data::partition::label_skew(&ds, n_edges, alpha, &mut rng)
            } else {
                ol4el::data::partition::iid(&ds, n_edges, &mut rng)
            };
            let mut seen: Vec<usize> =
                shards.iter().flat_map(|s| s.indices.clone()).collect();
            seen.sort_unstable();
            prop_assert!(seen.len() == n_rows, "covered {} of {n_rows}", seen.len());
            prop_assert!(
                seen == (0..n_rows).collect::<Vec<_>>(),
                "partition is not an exact cover"
            );
            prop_assert!(shards.iter().all(|s| !s.is_empty()), "empty shard");
            Ok(())
        },
    );
}

#[test]
fn prop_strategy_snapshot_restore_roundtrip() {
    // Checkpoint obligation, stated as a property: for any built-in
    // strategy warmed up by an arbitrary select/feedback history, a fresh
    // instance restored from its snapshot behaves bit-identically — same
    // arm choices under equal-seeded RNG streams, same histogram, and the
    // re-taken snapshot is the identical JSON document.
    property(
        0x5A,
        30,
        |g| {
            let mode = *g.choice(&["sync", "async"]);
            let name = *g.choice(&["ol4el", "fixed-i", "greedy-budget"]);
            let spec = if mode == "sync" && g.bool() {
                "ac-sync".to_string()
            } else {
                format!("{name}:mode={mode}")
            };
            let n_edges = g.int(2, 4);
            let hetero = g.float(1.0, 6.0);
            let slowdowns = g.vec(n_edges, |g| g.float(1.0, hetero));
            let warmup = g.int(1, 25);
            let seed = g.rng.next_u64();
            (spec, slowdowns, warmup, seed)
        },
        |(spec, slowdowns, warmup, seed)| {
            let cfg = RunConfig {
                strategy: StrategySpec::parse(spec).map_err(|e| e.to_string())?,
                n_edges: slowdowns.len(),
                ..Default::default()
            };
            let mut a = strategy::build(&cfg, slowdowns).map_err(|e| e.to_string())?;
            let sync = a.is_sync();
            let edge_of = |step: usize| if sync { 0 } else { step % slowdowns.len() };
            let mut warm_rng = Rng::new(*seed);
            for step in 0..*warmup {
                let e = edge_of(step);
                if let Some(tau) = a.select(e, 1e12, &mut warm_rng) {
                    a.feedback(e, tau, warm_rng.f64(), tau as f64 * 40.0 + 60.0);
                }
            }
            let snap = a.snapshot().map_err(|e| e.to_string())?;
            let mut b = strategy::build(&cfg, slowdowns).map_err(|e| e.to_string())?;
            b.restore(&snap).map_err(|e| e.to_string())?;
            let mut ra = Rng::new(seed.wrapping_add(1));
            let mut rb = Rng::new(seed.wrapping_add(1));
            for step in 0..20 {
                let e = edge_of(step);
                let pa = a.select(e, 1e12, &mut ra);
                let pb = b.select(e, 1e12, &mut rb);
                prop_assert!(
                    pa == pb,
                    "{spec}: step {step} diverged after restore: {pa:?} vs {pb:?}"
                );
                if let Some(tau) = pa {
                    let u = 0.2 + 0.1 * (step % 7) as f64;
                    let cost = tau as f64 * 40.0 + 60.0;
                    a.feedback(e, tau, u, cost);
                    b.feedback(e, tau, u, cost);
                }
            }
            prop_assert!(
                a.tau_histogram() == b.tau_histogram(),
                "{spec}: tau histograms diverged after restore"
            );
            let ja = a.snapshot().map_err(|e| e.to_string())?.to_string();
            let jb = b.snapshot().map_err(|e| e.to_string())?.to_string();
            prop_assert!(ja == jb, "{spec}: snapshot does not round-trip:\n{ja}\nvs\n{jb}");
            Ok(())
        },
    );
}

#[test]
fn prop_bandit_snapshot_restore_roundtrip() {
    // Same obligation one layer down: every in-tree budgeted-bandit
    // policy restored from a snapshot continues the select/update stream
    // bit-identically to the original instance.
    property(
        0x5B,
        40,
        |g| {
            let name = *g.choice(&["kube", "ucb-bv", "ucb1", "eps-greedy", "thompson"]);
            let n_arms = g.int(1, 8);
            let costs = g.vec(n_arms, |g| g.float(5.0, 120.0));
            let warmup = g.int(0, 40);
            let seed = g.rng.next_u64();
            (name.to_string(), costs, warmup, seed)
        },
        |(name, costs, warmup, seed)| {
            let kind = BanditSpec::parse(name).ok_or_else(|| format!("bad kind {name}"))?;
            let mut a = bandit::build(&kind, costs.clone());
            let mut warm_rng = Rng::new(*seed);
            for _ in 0..*warmup {
                if let Some(k) = a.select(1e12, &mut warm_rng) {
                    a.update(k, warm_rng.f64(), costs[k] * (0.8 + 0.4 * warm_rng.f64()));
                }
            }
            let snap = a.snapshot().map_err(|e| e.to_string())?;
            let mut b = bandit::build(&kind, costs.clone());
            b.restore(&snap).map_err(|e| e.to_string())?;
            let mut ra = Rng::new(seed.wrapping_add(1));
            let mut rb = Rng::new(seed.wrapping_add(1));
            for step in 0..25 {
                let ka = a.select(1e12, &mut ra);
                let kb = b.select(1e12, &mut rb);
                prop_assert!(
                    ka == kb,
                    "{name}: step {step} diverged after restore: {ka:?} vs {kb:?}"
                );
                if let Some(k) = ka {
                    let reward = 0.2 + 0.6 * (step % 7) as f64 / 7.0;
                    let cost = costs[k] * (0.85 + 0.01 * (step % 9) as f64);
                    a.update(k, reward, cost);
                    b.update(k, reward, cost);
                }
            }
            let ja = a.snapshot().map_err(|e| e.to_string())?.to_string();
            let jb = b.snapshot().map_err(|e| e.to_string())?.to_string();
            prop_assert!(ja == jb, "{name}: snapshot does not round-trip:\n{ja}\nvs\n{jb}");
            Ok(())
        },
    );
}

#[test]
fn prop_rng_save_restore_resumes_exact_stream() {
    // The RNG is the last carrier of hidden state: saving (`state`) and
    // restoring at an ARBITRARY cut point — including between the two
    // halves of a Box–Muller pair, where the spare gaussian is live —
    // must resume the exact draw sequence, whatever mix of draw kinds
    // follows the cut.
    property(
        0x5C,
        80,
        |g| {
            let seed = g.rng.next_u64();
            let prefix = g.int(0, 64);
            let tail = g.int(1, 64);
            let kinds = g.vec(prefix + tail, |g| g.int(0, 2));
            (seed, prefix, kinds)
        },
        |(seed, prefix, kinds)| {
            fn draw(r: &mut Rng, kind: usize) -> u64 {
                match kind {
                    0 => r.next_u64(),
                    1 => r.f64().to_bits(),
                    _ => r.normal().to_bits(),
                }
            }
            let mut r = Rng::new(*seed);
            for &k in &kinds[..*prefix] {
                draw(&mut r, k);
            }
            let (words, spare) = r.state();
            let expect: Vec<u64> = kinds[*prefix..].iter().map(|&k| draw(&mut r, k)).collect();
            let mut q = Rng::restore(words, spare);
            let got: Vec<u64> = kinds[*prefix..].iter().map(|&k| draw(&mut q, k)).collect();
            prop_assert!(
                expect == got,
                "restored stream diverged at cut {prefix}: {expect:?} vs {got:?}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_label_skew_respects_partition_kind_parse() {
    property(
        0xD2,
        40,
        |g| g.float(0.01, 10.0),
        |&alpha| {
            let s = format!("skew:{alpha}");
            match PartitionKind::parse(&s) {
                Some(PartitionKind::LabelSkew { alpha: a }) => {
                    prop_assert!((a - alpha).abs() < 1e-9, "parsed {a} != {alpha}");
                    Ok(())
                }
                other => Err(format!("parse '{s}' gave {other:?}")),
            }
        },
    );
}
