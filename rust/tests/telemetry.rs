//! The telemetry layer's out-of-band contract, end to end: enabling
//! instrumentation (a live sink + sampling) must not perturb a single
//! bit of any run — telemetry reads wall-clock and atomics, never an RNG
//! stream, event queue, or charge ledger.
//!
//! This is an integration test binary on purpose: the telemetry sink and
//! sample rate are process-global, so the install/run/uninstall sequence
//! below runs inside ONE test fn and never races the library's own unit
//! tests (separate process).

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use ol4el::config::RunConfig;
use ol4el::coordinator::observer::from_fn;
use ol4el::coordinator::RunEvent;
use ol4el::net::{ChurnSpec, FleetSim, NetworkSpec};
use ol4el::strategy::StrategySpec;
use ol4el::telemetry;
use ol4el::util::json::Json;

/// Run a fleet at `shards`, capturing the complete event stream.
fn run_captured(cfg: RunConfig, shards: usize) -> Vec<RunEvent> {
    let events = Rc::new(RefCell::new(Vec::new()));
    let sink = events.clone();
    FleetSim::new(cfg)
        .unwrap()
        .shards(shards)
        .observe(from_fn(move |ev: &RunEvent| {
            sink.borrow_mut().push(ev.clone());
        }))
        .run()
        .unwrap();
    Rc::try_unwrap(events).unwrap().into_inner()
}

fn equivalence_cfg(strategy: StrategySpec, seed: u64) -> RunConfig {
    RunConfig {
        strategy,
        n_edges: 60,
        hetero: 4.0,
        budget: 900.0,
        data_n: 3000, // ignored by the fleet; satisfies validate()
        eval_every: 20,
        network: NetworkSpec::parse("lognormal:5:0.5,drop:0.02").unwrap(),
        churn: ChurnSpec::parse("poisson:0.2,join:1,restart:400,straggle:0.1:3").unwrap(),
        seed,
        ..Default::default()
    }
}

/// ONE test fn on purpose: install/uninstall mutate process-global state,
/// and the default test runner is multi-threaded — a second telemetry
/// test in this binary would race the sink. Everything sequences here.
#[test]
fn telemetry_on_is_bit_identical_to_telemetry_off() {
    // -- baseline: telemetry uninstalled, sample untouched ----------------
    let async_cfg = equivalence_cfg(StrategySpec::ol4el_async(), 11);
    let sync_cfg = equivalence_cfg(StrategySpec::ol4el_sync(), 23);
    let base_async_1 = run_captured(async_cfg.clone(), 1);
    let base_async_4 = run_captured(async_cfg.clone(), 4);
    let base_sync_1 = run_captured(sync_cfg.clone(), 1);
    let base_sync_4 = run_captured(sync_cfg.clone(), 4);
    assert_eq!(
        base_async_1, base_async_4,
        "sharding contract broken before telemetry even engages"
    );
    assert_eq!(base_sync_1, base_sync_4, "sync sharding contract broken");

    // -- telemetry ON: live sink, aggressive sampling ---------------------
    let sink = Arc::new(telemetry::VecSink::new());
    telemetry::install(sink.clone(), 3);
    assert!(telemetry::active(), "install must arm the sink");

    let tele_async_1 = run_captured(async_cfg.clone(), 1);
    let tele_async_4 = run_captured(async_cfg, 4);
    let tele_sync_1 = run_captured(sync_cfg.clone(), 1);
    let tele_sync_4 = run_captured(sync_cfg, 4);
    telemetry::flush();
    let records = sink.take();
    telemetry::uninstall();
    telemetry::set_sample(1);
    assert!(!telemetry::active(), "uninstall must disarm the sink");

    // The out-of-band contract: instrumentation changed NOTHING.
    assert_eq!(base_async_1, tele_async_1, "async 1-shard diverged under telemetry");
    assert_eq!(base_async_4, tele_async_4, "async 4-shard diverged under telemetry");
    assert_eq!(base_sync_1, tele_sync_1, "sync 1-shard diverged under telemetry");
    assert_eq!(base_sync_4, tele_sync_4, "sync 4-shard diverged under telemetry");

    // -- and the sink actually observed the run ---------------------------
    assert!(
        !records.is_empty(),
        "telemetry-on runs must emit records into the sink"
    );
    let tag = |r: &Json| r.get("t").and_then(Json::as_str).map(str::to_string);
    assert!(
        records.iter().any(|r| tag(r).as_deref() == Some("meta")),
        "install must emit a meta record"
    );
    assert!(
        records.iter().any(|r| tag(r).as_deref() == Some("span")),
        "sampled spans must stream into the sink"
    );
    assert!(
        records.iter().any(|r| tag(r).as_deref() == Some("counter")),
        "flush must snapshot counters"
    );
    assert!(
        records.iter().any(|r| tag(r).as_deref() == Some("hist")),
        "flush must snapshot histograms"
    );

    // Records from all three instrumented layers: the decision layer
    // (session.*), the shard loop (fleet.*) and the transport (transport.*).
    let names: Vec<String> = records
        .iter()
        .filter_map(|r| r.get("name").and_then(Json::as_str).map(str::to_string))
        .collect();
    for layer in ["session.", "fleet.", "transport."] {
        assert!(
            names.iter().any(|n| n.starts_with(layer)),
            "no record from the {layer}* layer (got {names:?})"
        );
    }

    // Core counters counted: the shard loop popped events and the
    // strategy layer made selections.
    let counter_value = |name: &str| -> f64 {
        records
            .iter()
            .filter(|r| tag(r).as_deref() == Some("counter"))
            .filter(|r| r.get("name").and_then(Json::as_str) == Some(name))
            .filter_map(|r| r.get("value").and_then(Json::as_f64))
            .next_back()
            .unwrap_or(0.0)
    };
    assert!(counter_value("fleet.shard.events") > 0.0, "no events counted");
    assert!(counter_value("session.selects") > 0.0, "no selects counted");
    assert!(counter_value("transport.sent") > 0.0, "no sends counted");
}
