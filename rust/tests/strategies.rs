//! The open-strategy-layer acceptance tests.
//!
//! 1. **Wire coverage** — strategy specs survive config → JSON → config
//!    across every registered strategy × task × manner, and the legacy
//!    `algo` / `bandit` / `fixed_interval` wire trio canonicalizes into
//!    the same [`StrategySpec`]s.
//! 2. **Legacy regression guard** — the migrated strategies transcribe
//!    the deleted `Algo`-dispatch selection/update order line for line;
//!    with no pre-refactor binary in the offline image, the guard asserts
//!    what is mechanically checkable: fixed-seed event streams are
//!    exactly reproducible for all four legacy policies (sync + async
//!    manners, native engine).
//! 3. **The API is actually open** — the deadline-aware `greedy-budget`
//!    policy runs end-to-end through train, suite and a 5000-edge fleet,
//!    and a strategy registered at runtime from *outside* the crate (this
//!    test file) trains through Session and the sharded FleetSim with
//!    1-vs-4-shard bit-equality.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::{Arc, Mutex};

use anyhow::Result;
use ol4el::config::RunConfig;
use ol4el::coordinator::{self, find_outcome, observer, ExperimentSuite, RunEvent, Session};
use ol4el::engine::native::NativeEngine;
use ol4el::harness::paper_strategies;
use ol4el::model::TaskSpec;
use ol4el::net::{ChurnSpec, FleetSim, NetworkSpec};
use ol4el::strategy::{
    self, registry::always_valid, Strategy, StrategyCtx, StrategyFactory, StrategySpec,
};
use ol4el::util::json::Json;
use ol4el::util::rng::Rng;

fn cfg(strategy: StrategySpec) -> RunConfig {
    RunConfig {
        strategy,
        task: TaskSpec::svm(),
        n_edges: 3,
        budget: 1500.0,
        data_n: 4000,
        seed: 11,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// 1. Wire coverage
// ---------------------------------------------------------------------------

#[test]
fn every_registered_strategy_roundtrips_the_wire_across_tasks_and_manners() {
    ensure_cycle_registered();
    let tasks = ["svm", "kmeans:k=5", "logreg", "gmm:k=3"];
    for (name, _about) in strategy::registered_strategies() {
        let base = StrategySpec::parse(name).unwrap();
        for sync in [true, false] {
            // Skip manners the strategy declares it cannot run under
            // (ac-sync is barrier-only).
            let Ok(spec) = base.with_mode(sync) else { continue };
            for task in tasks {
                let cfg = RunConfig {
                    strategy: spec.clone(),
                    task: TaskSpec::parse(task).unwrap(),
                    seed: 9,
                    ..Default::default()
                };
                let back = RunConfig::from_json(&cfg.to_json()).unwrap();
                assert_eq!(back.strategy, spec, "{name} x {task} x sync={sync}");
                assert_eq!(back.strategy.is_sync(), sync, "{name} lost its manner");
                assert_eq!(back.task, cfg.task);
            }
        }
    }
}

#[test]
fn legacy_wire_fields_parse_to_the_same_canonical_spec() {
    // {"algo": ..., "bandit": ...} from the enum era keeps working and
    // lands on the exact spec the new field would carry.
    let legacy = |edits: &[(&str, Json)]| {
        let mut j = RunConfig::default().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("strategy");
            for (k, v) in edits {
                map.insert(k.to_string(), v.clone());
            }
        }
        RunConfig::from_json(&j).unwrap().strategy
    };
    assert_eq!(
        legacy(&[("algo", Json::str("ac-sync")), ("bandit", Json::str("kube"))]),
        StrategySpec::ac_sync()
    );
    assert_eq!(
        legacy(&[
            ("algo", Json::str("ol4el-sync")),
            ("bandit", Json::str("eps-greedy:0.05")),
        ]),
        StrategySpec::parse("ol4el:bandit=eps-greedy:eps=0.05:mode=sync").unwrap()
    );
    assert_eq!(
        legacy(&[("algo", Json::str("fixed-i")), ("fixed_interval", Json::num(2.0))]),
        StrategySpec::parse("fixed-i:i=2").unwrap()
    );
    // And a full run from a legacy-shaped config equals the same run from
    // the canonical spec (the wire shapes are one config).
    let engine = NativeEngine::default();
    let mut j = cfg(StrategySpec::ol4el_sync()).to_json();
    if let Json::Obj(map) = &mut j {
        map.remove("strategy");
        map.insert("algo".to_string(), Json::str("ol4el-sync"));
        map.insert("bandit".to_string(), Json::str("auto"));
    }
    let from_legacy = RunConfig::from_json(&j).unwrap();
    let a = coordinator::run(&from_legacy, &engine).unwrap();
    let b = coordinator::run(&cfg(StrategySpec::ol4el_sync()), &engine).unwrap();
    assert_eq!(a.final_metric, b.final_metric);
    assert_eq!(a.total_updates, b.total_updates);
    assert_eq!(a.tau_histogram, b.tau_histogram);
}

// ---------------------------------------------------------------------------
// 2. Legacy regression guard
// ---------------------------------------------------------------------------

/// Capture a run's full event stream as Debug strings (f64s print with
/// shortest-round-trip precision, so string equality IS bit-for-bit
/// equality of every payload).
fn event_stream(c: &RunConfig) -> (Vec<String>, coordinator::RunResult) {
    let engine = NativeEngine::default();
    let seen = Arc::new(Mutex::new(Vec::new()));
    let sink = seen.clone();
    let mut session = Session::new(c, &engine).unwrap();
    session.observe(observer::from_fn(move |ev: &RunEvent| {
        sink.lock().unwrap().push(format!("{ev:?}"));
    }));
    let result = session.run().unwrap();
    let stream = seen.lock().unwrap().clone();
    (stream, result)
}

#[test]
fn fixed_seed_event_streams_reproduce_exactly_for_all_legacy_strategies() {
    // The four policies the deleted Algo enum dispatched must stay
    // deterministic to the bit through the registry path (the selection /
    // update order is a line-for-line transcription of the enum-era code).
    for strategy in paper_strategies() {
        let c = cfg(strategy.clone());
        let (s1, r1) = event_stream(&c);
        let (s2, r2) = event_stream(&c);
        assert_eq!(s1.len(), s2.len(), "{strategy}");
        for (k, (a, b)) in s1.iter().zip(&s2).enumerate() {
            assert_eq!(a, b, "{strategy}: event {k} diverged");
        }
        assert!(r1.total_updates > 0, "{strategy}: no updates");
        assert_eq!(r1.final_metric, r2.final_metric);
        assert_eq!(r1.trace, r2.trace);
        assert_eq!(r1.tau_histogram, r2.tau_histogram);
    }
}

// ---------------------------------------------------------------------------
// 3a. greedy-budget end to end (the in-tree openness proof)
// ---------------------------------------------------------------------------

#[test]
fn greedy_budget_trains_both_manners_and_honors_its_deadline() {
    let engine = NativeEngine::default();
    for sync in [false, true] {
        let spec = StrategySpec::greedy_budget().with_mode(sync).unwrap();
        let c = cfg(spec.clone());
        let r = coordinator::run(&c, &engine).unwrap();
        assert!(r.total_updates > 0, "{spec}: no updates");
        let first = r.trace.first().unwrap().metric;
        assert!(
            r.final_metric > first,
            "{spec}: no learning: {first:.3} -> {:.3}",
            r.final_metric
        );
    }
    // A tight per-slot deadline caps τ below what the budget would allow:
    // the pull histogram must stay inside the affordable prefix.
    let c = cfg(StrategySpec::parse("greedy-budget:deadline=200").unwrap());
    let r = coordinator::run(&c, &engine).unwrap();
    let affordable = (1..=c.tau_max)
        .filter(|&t| c.cost.nominal_arm_cost(t, 1.0) <= 200.0)
        .max()
        .unwrap_or(0);
    let max_pulled = r
        .tau_histogram
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(i, _)| i + 1)
        .max()
        .unwrap_or(0);
    assert!(
        max_pulled <= affordable,
        "deadline ignored: pulled τ={max_pulled}, affordable max τ={affordable}"
    );
    // Without a deadline the greedy policy reaches for the largest arm.
    let free = coordinator::run(&cfg(StrategySpec::greedy_budget()), &engine).unwrap();
    let max_free = free
        .tau_histogram
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(i, _)| i + 1)
        .max()
        .unwrap_or(0);
    assert!(max_free > max_pulled, "deadline had no observable effect");
}

#[test]
fn greedy_budget_sweeps_through_the_suite() {
    let base = RunConfig {
        data_n: 3000,
        budget: 600.0,
        n_edges: 3,
        seed: 1,
        ..Default::default()
    };
    let strategies = [
        StrategySpec::ol4el_async(),
        StrategySpec::greedy_budget(),
        StrategySpec::greedy_budget().with_mode(true).unwrap(),
    ];
    let outs = ExperimentSuite::new("greedy", base)
        .strategies(strategies.clone())
        .run_native()
        .unwrap();
    assert_eq!(outs.len(), 3);
    for spec in &strategies {
        let out = find_outcome(&outs, &TaskSpec::svm(), spec, 3, 1.0).unwrap();
        assert!(out.agg.metric.mean() > 0.0, "{spec}: empty metric");
        assert!(out.agg.updates.mean() > 0.0, "{spec}: no updates");
    }
}

#[test]
fn greedy_budget_runs_a_5000_edge_fleet() {
    // The same acceptance shape as the net:: PR's 5000-edge run, now with
    // the out-of-enum strategy making every interval decision.
    let c = RunConfig {
        strategy: StrategySpec::parse("greedy-budget:deadline=900").unwrap(),
        n_edges: 5000,
        hetero: 6.0,
        budget: 1200.0,
        data_n: 20_000,
        eval_every: 1000,
        network: NetworkSpec::parse("lognormal:5:0.5,drop:0.02").unwrap(),
        churn: ChurnSpec::parse("poisson:0.05,join:10").unwrap(),
        seed: 17,
        ..Default::default()
    };
    let r = FleetSim::new(c).unwrap().run().unwrap();
    assert_eq!(r.n_edges, 5000);
    assert!(r.updates > 5000, "greedy-budget fleet updates {}", r.updates);
    assert!(r.retired > 0);
}

#[test]
fn greedy_budget_fleet_sharding_stays_exact() {
    let c = RunConfig {
        strategy: StrategySpec::greedy_budget(),
        n_edges: 120,
        hetero: 4.0,
        budget: 1200.0,
        eval_every: 50,
        data_n: 20_000,
        network: NetworkSpec::parse("uniform:2:10,drop:0.02").unwrap(),
        churn: ChurnSpec::parse("poisson:0.2,join:1,restart:400").unwrap(),
        seed: 9,
        ..Default::default()
    };
    let one = FleetSim::new(c.clone()).unwrap().shards(1).run().unwrap();
    let four = FleetSim::new(c).unwrap().shards(4).run().unwrap();
    assert!(one.updates > 0, "fleet made no updates");
    assert_eq!(one.updates, four.updates);
    assert_eq!(one.wall_ms, four.wall_ms);
    assert_eq!(one.mean_spent, four.mean_spent);
    assert_eq!(one.messages_sent, four.messages_sent);
    assert_eq!(one.events, four.events);
}

// ---------------------------------------------------------------------------
// 3b. Openness: a strategy registered at runtime, from outside the crate
// ---------------------------------------------------------------------------

/// A deliberately minimal deterministic policy: cycle τ = 1, 2, …, τ_max
/// per decision slot, independently per edge, falling back to τ = 1 (or
/// retiring) when the cycled arm is unaffordable. No RNG and per-edge
/// state only, so it is placement-independent on the sharded fleet.
struct CycleStrategy {
    arm_costs: Vec<Vec<f64>>,
    next: Vec<usize>,
    pulls: Vec<u64>,
    sync: bool,
}

impl Strategy for CycleStrategy {
    fn name(&self) -> String {
        "cycle".to_string()
    }
    fn is_sync(&self) -> bool {
        self.sync
    }
    fn select(&mut self, edge: usize, remaining_budget: f64, _rng: &mut Rng) -> Option<usize> {
        let idx = if self.sync { 0 } else { edge };
        let tau_max = self.arm_costs[idx].len();
        let tau = 1 + (self.next[idx] % tau_max);
        self.next[idx] += 1;
        let pick = if self.arm_costs[idx][tau - 1] <= remaining_budget {
            tau
        } else if self.arm_costs[idx][0] <= remaining_budget {
            1
        } else {
            return None;
        };
        self.pulls[pick - 1] += 1;
        Some(pick)
    }
    fn feedback(&mut self, _edge: usize, _tau: usize, _utility: f64, _cost: f64) {}
    fn on_edge_joined(&mut self, edge: usize, arm_costs: Vec<f64>) {
        if self.sync {
            return;
        }
        assert_eq!(edge, self.arm_costs.len());
        self.arm_costs.push(arm_costs);
        self.next.push(0);
    }
    fn tau_histogram(&self) -> Vec<u64> {
        self.pulls.clone()
    }
}

fn cycle_canon(_p: &mut ol4el::strategy::StrategyParams) -> Result<String> {
    Ok(String::new())
}

fn cycle_build(spec: &StrategySpec, ctx: &StrategyCtx) -> Result<Box<dyn Strategy>> {
    let mut p = spec.params();
    // The registry resolved the manner at parse time; the canonical spec
    // is the single source (never re-hardcode the default in build).
    let sync = spec.is_sync();
    let _ = p.take_mode()?;
    p.finish("cycle")?;
    let arm_costs = ctx.arm_costs(sync);
    let n = arm_costs.len();
    Ok(Box::new(CycleStrategy {
        arm_costs,
        next: vec![0; n],
        pulls: vec![0; ctx.cfg.tau_max],
        sync,
    }))
}

fn cycle_factory() -> StrategyFactory {
    StrategyFactory {
        name: "cycle",
        about: "test-only deterministic τ cycler",
        sync_ok: true,
        async_ok: true,
        default_sync: false,
        canon: cycle_canon,
        check: always_valid,
        build: cycle_build,
    }
}

fn ensure_cycle_registered() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| strategy::register(cycle_factory()).unwrap());
}

#[test]
fn runtime_registered_strategy_runs_end_to_end() {
    ensure_cycle_registered();

    // The spec now parses everywhere a strategy name does...
    let spec = StrategySpec::parse("cycle").unwrap();
    assert_eq!(spec.name(), "cycle");
    assert!(!spec.is_sync());
    // ...survives the JSON wire format...
    let c = cfg(spec.clone());
    let back = RunConfig::from_json(&c.to_json()).unwrap();
    assert_eq!(back.strategy, c.strategy);
    // ...and trains end-to-end through the standard session machinery
    // under BOTH manners (mode= is honored like any in-tree strategy).
    let engine = NativeEngine::default();
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.total_updates > 0);
    // The cycler's signature: multiple distinct arms pulled.
    assert!(r.tau_histogram.iter().filter(|&&n| n > 0).count() > 1);
    let sync_cfg = cfg(spec.with_mode(true).unwrap());
    let rs = coordinator::run(&sync_cfg, &engine).unwrap();
    assert!(rs.total_updates > 0);

    // Unknown-parameter rejection flows through the factory's finish().
    assert!(StrategySpec::parse("cycle:k=2").is_err());
}

#[test]
fn runtime_registered_strategy_fleet_sharding_stays_exact() {
    // The acceptance bar: a strategy the crate has never heard of drives
    // the sharded fleet simulator through the same public registry path,
    // and 1-shard vs 4-shard runs stay bit-identical (per-edge instances
    // are built wherever the edge lives).
    ensure_cycle_registered();
    let c = RunConfig {
        strategy: StrategySpec::parse("cycle").unwrap(),
        n_edges: 120,
        hetero: 4.0,
        budget: 1200.0,
        eval_every: 50,
        data_n: 20_000,
        network: NetworkSpec::parse("uniform:2:10,drop:0.02").unwrap(),
        churn: ChurnSpec::parse("poisson:0.2,join:1,restart:400").unwrap(),
        seed: 9,
        ..Default::default()
    };
    let capture = |cfg: RunConfig, shards: usize| {
        let events = Rc::new(RefCell::new(Vec::new()));
        let sink = events.clone();
        let report = FleetSim::new(cfg)
            .unwrap()
            .shards(shards)
            .observe(observer::from_fn(move |ev: &RunEvent| {
                sink.borrow_mut().push(ev.clone());
            }))
            .run()
            .unwrap();
        (Rc::try_unwrap(events).unwrap().into_inner(), report)
    };
    let (ev1, one) = capture(c.clone(), 1);
    let (ev4, four) = capture(c, 4);
    assert!(one.updates > 0, "cycle fleet made no updates");
    assert_eq!(ev1, ev4, "cycle: sharded event stream diverged");
    assert_eq!(one.updates, four.updates);
    assert_eq!(one.wall_ms, four.wall_ms);
    assert_eq!(one.mean_spent, four.mean_spent);
    assert_eq!(one.messages_sent, four.messages_sent);
}
