//! The three-layer correctness closure: the PJRT engine's fused AOT
//! kernels (Pallas L1 + JAX L2, lowered to HLO) must agree numerically
//! with the learners' portable path on the native oracle, step by step
//! and end to end. The fused kernels are keyed by learner name in the
//! artifact manifest ("svm_step", "kmeans_eval", ...).
//!
//! These tests are skipped (with a loud message) when artifacts/ has not
//! been built — run `make artifacts` first. CI runs them always.

use ol4el::edge::Hyper;
use ol4el::engine::native::NativeEngine;
use ol4el::engine::pjrt::PjrtEngine;
use ol4el::engine::ComputeEngine;
use ol4el::model::{Learner as _, TaskSpec};
use ol4el::util::rng::Rng;

fn pjrt() -> Option<PjrtEngine> {
    match PjrtEngine::open("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP pjrt parity: {err}");
            None
        }
    }
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn close64(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn svm_step_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    assert!(pj.has_kernel("svm_step"), "manifest lost svm_step");
    let learner = TaskSpec::svm().learner();
    let s = *pj.shapes();
    let mut rng = Rng::new(0);
    let x: Vec<f32> = (0..s.svm_batch * s.svm_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let y: Vec<i32> = (0..s.svm_batch)
        .map(|_| rng.below(s.svm_c) as i32)
        .collect();
    let mut p_nat: Vec<f32> = (0..s.svm_param_len())
        .map(|_| rng.normal() as f32 * 0.1)
        .collect();
    let mut p_pj = p_nat.clone();
    let hyper = Hyper {
        lr: 0.05,
        reg: 1e-4,
        lr_decay: 0.0,
    };

    for step in 0..5 {
        let out_nat = learner
            .local_step(&nat, &mut p_nat, &x, &y, &hyper)
            .unwrap();
        let out_pj = learner.local_step(&pj, &mut p_pj, &x, &y, &hyper).unwrap();
        assert!(
            close64(out_nat.signal, out_pj.signal, 1e-4),
            "step {step}: loss {} vs {}",
            out_nat.signal,
            out_pj.signal
        );
        for (i, (a, b)) in p_nat.iter().zip(&p_pj).enumerate() {
            assert!(close(*a, *b, 1e-4), "step {step}, param {i}: {a} vs {b}");
        }
    }
}

#[test]
fn svm_eval_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let learner = TaskSpec::svm().learner();
    let s = *pj.shapes();
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..s.svm_eval_batch * s.svm_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let y: Vec<i32> = (0..s.svm_eval_batch)
        .map(|_| rng.below(s.svm_c) as i32)
        .collect();
    let p: Vec<f32> = (0..s.svm_param_len())
        .map(|_| rng.normal() as f32 * 0.2)
        .collect();
    let m_nat = learner.evaluate(&nat, &p, &x, &y).unwrap();
    let m_pj = learner.evaluate(&pj, &p, &x, &y).unwrap();
    assert_eq!(m_nat, m_pj, "accuracy mismatch");
}

#[test]
fn kmeans_step_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    assert!(pj.has_kernel("kmeans_step"), "manifest lost kmeans_step");
    let learner = TaskSpec::kmeans().learner();
    let s = *pj.shapes();
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..s.km_batch * s.km_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let centers: Vec<f32> = (0..s.km_param_len())
        .map(|_| rng.normal() as f32)
        .collect();
    let hyper = Hyper::default();
    let mut c_nat = centers.clone();
    let mut c_pj = centers;
    let out_nat = learner.local_step(&nat, &mut c_nat, &x, &[], &hyper).unwrap();
    let out_pj = learner.local_step(&pj, &mut c_pj, &x, &[], &hyper).unwrap();
    assert!(
        close64(out_nat.signal, out_pj.signal, 1e-3),
        "inertia {} vs {}",
        out_nat.signal,
        out_pj.signal
    );
    for (i, (a, b)) in c_nat.iter().zip(&c_pj).enumerate() {
        assert!(close(*a, *b, 1e-4), "center coord {i}: {a} vs {b}");
    }
}

#[test]
fn kmeans_eval_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let learner = TaskSpec::kmeans().learner();
    let s = *pj.shapes();
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..s.km_eval_batch * s.km_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let centers: Vec<f32> = (0..s.km_param_len())
        .map(|_| rng.normal() as f32)
        .collect();
    let y: Vec<i32> = (0..s.km_eval_batch).map(|i| (i % s.km_k) as i32).collect();
    let m_nat = learner.evaluate(&nat, &centers, &x, &y).unwrap();
    let m_pj = learner.evaluate(&pj, &centers, &x, &y).unwrap();
    assert_eq!(m_nat, m_pj, "clustering F1 mismatch");
}

#[test]
fn tasks_without_artifacts_fall_back_to_portable_path() {
    // The open-task contract on the production backend: a learner with no
    // fused kernels (logreg, gmm) still runs on pjrt, numerically equal
    // to the native path because both take the portable route.
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    for spec in [TaskSpec::logreg(), TaskSpec::gmm()] {
        let learner = spec.learner();
        assert!(!pj.has_kernel(&format!("{}_step", learner.name())));
        let mut rng = Rng::new(4);
        let ds = learner.synth(1024, 2.5, &mut rng);
        let mut p_nat = learner.init_params(&ds, &mut rng);
        let mut p_pj = p_nat.clone();
        let n = learner.batch();
        let x = ds.x[..n * ds.d].to_vec();
        let y = ds.y[..n].to_vec();
        let hyper = Hyper::default();
        let a = learner.local_step(&nat, &mut p_nat, &x, &y, &hyper).unwrap();
        let b = learner.local_step(&pj, &mut p_pj, &x, &y, &hyper).unwrap();
        assert_eq!(a.signal, b.signal, "{}", learner.name());
        assert_eq!(p_nat, p_pj, "{}", learner.name());
    }
}

#[test]
fn end_to_end_run_parity() {
    // A short full training run must produce near-identical results on
    // both engines (same seed, same data, same coordination decisions —
    // only the compute backend differs).
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let cfg = ol4el::config::RunConfig {
        task: TaskSpec::svm(),
        strategy: ol4el::strategy::StrategySpec::ol4el_sync(),
        n_edges: 2,
        budget: 500.0,
        data_n: 2000,
        seed: 9,
        ..Default::default()
    };
    let r_nat = ol4el::coordinator::run(&cfg, &nat).unwrap();
    let r_pj = ol4el::coordinator::run(&cfg, &pj).unwrap();
    assert_eq!(r_nat.total_updates, r_pj.total_updates);
    assert!(
        (r_nat.final_metric - r_pj.final_metric).abs() < 0.02,
        "metric {} vs {}",
        r_nat.final_metric,
        r_pj.final_metric
    );
}

#[test]
fn manifest_shapes_match_engine_contract() {
    let Some(pj) = pjrt() else { return };
    assert_eq!(*pj.shapes(), ol4el::engine::Shapes::default());
    assert_eq!(pj.name(), "pjrt");
}
