//! The three-layer correctness closure: the PJRT engine (Pallas L1 + JAX
//! L2, AOT-lowered to HLO) must agree numerically with the native Rust
//! oracle, step by step and end to end.
//!
//! These tests are skipped (with a loud message) when artifacts/ has not
//! been built — run `make artifacts` first. CI runs them always.

use ol4el::engine::native::NativeEngine;
use ol4el::engine::pjrt::PjrtEngine;
use ol4el::engine::ComputeEngine;
use ol4el::util::rng::Rng;

fn pjrt() -> Option<PjrtEngine> {
    match PjrtEngine::open("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP pjrt parity: {err}");
            None
        }
    }
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn svm_step_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let s = *nat.shapes();
    let mut rng = Rng::new(0);
    let x: Vec<f32> = (0..s.svm_batch * s.svm_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let y: Vec<i32> = (0..s.svm_batch)
        .map(|_| rng.below(s.svm_c) as i32)
        .collect();
    let mut p_nat: Vec<f32> = (0..s.svm_param_len())
        .map(|_| rng.normal() as f32 * 0.1)
        .collect();
    let mut p_pj = p_nat.clone();

    for step in 0..5 {
        let out_nat = nat.svm_step(&mut p_nat, &x, &y, 0.05, 1e-4).unwrap();
        let out_pj = pj.svm_step(&mut p_pj, &x, &y, 0.05, 1e-4).unwrap();
        assert!(
            close(out_nat.loss, out_pj.loss, 1e-4),
            "step {step}: loss {} vs {}",
            out_nat.loss,
            out_pj.loss
        );
        for (i, (a, b)) in p_nat.iter().zip(&p_pj).enumerate() {
            assert!(
                close(*a, *b, 1e-4),
                "step {step}, param {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn svm_eval_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let s = *nat.shapes();
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..s.svm_eval_batch * s.svm_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let y: Vec<i32> = (0..s.svm_eval_batch)
        .map(|_| rng.below(s.svm_c) as i32)
        .collect();
    let p: Vec<f32> = (0..s.svm_param_len())
        .map(|_| rng.normal() as f32 * 0.2)
        .collect();
    let (c_nat, l_nat) = nat.svm_eval(&p, &x, &y).unwrap();
    let (c_pj, l_pj) = pj.svm_eval(&p, &x, &y).unwrap();
    assert_eq!(c_nat, c_pj, "correct-count mismatch");
    assert!(close(l_nat, l_pj, 1e-4), "loss {l_nat} vs {l_pj}");
}

#[test]
fn kmeans_step_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let s = *nat.shapes();
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..s.km_batch * s.km_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let centers: Vec<f32> = (0..s.km_param_len())
        .map(|_| rng.normal() as f32)
        .collect();
    let out_nat = nat.kmeans_step(&centers, &x).unwrap();
    let out_pj = pj.kmeans_step(&centers, &x).unwrap();
    assert_eq!(out_nat.counts, out_pj.counts, "count vector mismatch");
    for (i, (a, b)) in out_nat.sums.iter().zip(&out_pj.sums).enumerate() {
        assert!(close(*a, *b, 1e-4), "sums[{i}]: {a} vs {b}");
    }
    assert!(
        close(out_nat.inertia, out_pj.inertia, 1e-3),
        "inertia {} vs {}",
        out_nat.inertia,
        out_pj.inertia
    );
}

#[test]
fn kmeans_eval_parity() {
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let s = *nat.shapes();
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..s.km_eval_batch * s.km_d)
        .map(|_| rng.normal() as f32)
        .collect();
    let centers: Vec<f32> = (0..s.km_param_len())
        .map(|_| rng.normal() as f32)
        .collect();
    let (a_nat, i_nat) = nat.kmeans_eval(&centers, &x).unwrap();
    let (a_pj, i_pj) = pj.kmeans_eval(&centers, &x).unwrap();
    assert_eq!(a_nat, a_pj, "assignment mismatch");
    assert!(close(i_nat, i_pj, 1e-3), "inertia {i_nat} vs {i_pj}");
}

#[test]
fn end_to_end_run_parity() {
    // A short full training run must produce near-identical results on
    // both engines (same seed, same data, same coordination decisions —
    // only the compute backend differs).
    let Some(pj) = pjrt() else { return };
    let nat = NativeEngine::default();
    let cfg = ol4el::config::RunConfig {
        task: ol4el::model::Task::Svm,
        algo: ol4el::config::Algo::Ol4elSync,
        n_edges: 2,
        budget: 500.0,
        data_n: 2000,
        seed: 9,
        ..Default::default()
    };
    let r_nat = ol4el::coordinator::run(&cfg, &nat).unwrap();
    let r_pj = ol4el::coordinator::run(&cfg, &pj).unwrap();
    assert_eq!(r_nat.total_updates, r_pj.total_updates);
    assert!(
        (r_nat.final_metric - r_pj.final_metric).abs() < 0.02,
        "metric {} vs {}",
        r_nat.final_metric,
        r_pj.final_metric
    );
}

#[test]
fn manifest_shapes_match_engine_contract() {
    let Some(pj) = pjrt() else { return };
    assert_eq!(*pj.shapes(), ol4el::engine::Shapes::default());
    assert_eq!(pj.name(), "pjrt");
}
