//! The restart-equals-uninterrupted equality suite.
//!
//! Three facts are proven for every collaboration manner × task ×
//! built-in strategy cell:
//!
//! 1. Checkpointing is a pure side effect: a run that writes periodic
//!    snapshots emits the *same* event stream and final scalars as the
//!    same run without checkpointing (file I/O only, no RNG perturbed).
//! 2. Restart equals uninterrupted: resuming a mid-run snapshot replays
//!    the remainder of the run bit for bit — the resumed `RunResult`
//!    (final metric, updates, wall clock, ledgers, tau histogram, the
//!    full trace) equals the never-interrupted baseline, and the resumed
//!    event stream is exactly the baseline stream's suffix.
//! 3. The snapshot round-trips: resume + re-checkpoint at the same round
//!    reproduces the identical JSON document.

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use ol4el::config::RunConfig;
use ol4el::coordinator::observer::from_fn;
use ol4el::coordinator::{
    checkpoint, mode_for, CollaborationMode, RunEvent, RunResult, Session,
};
use ol4el::engine::native::NativeEngine;
use ol4el::model::TaskSpec;
use ol4el::strategy::StrategySpec;
use ol4el::util::json::Json;

/// A small-but-not-degenerate run: enough budget for several global
/// updates in every manner so a genuinely mid-run snapshot exists.
fn cfg(task: &str, strategy: &str) -> RunConfig {
    RunConfig {
        task: TaskSpec::parse(task).unwrap(),
        strategy: StrategySpec::parse(strategy).unwrap(),
        n_edges: 3,
        hetero: 3.0,
        budget: 1200.0,
        data_n: 3000,
        seed: 11,
        ..Default::default()
    }
}

/// A scratch directory unique to this test process + cell.
fn scratch(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ol4el-ckpt-{}-{}",
        std::process::id(),
        label.replace([':', '=', '/'], "_")
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run `cfg` to completion collecting the full event stream. When
/// `snapshot` is set, periodic checkpointing (cadence 1) writes to
/// `snapshot.0`, and the first `GlobalUpdate` event at `updates >= 2`
/// copies the then-latest snapshot aside to `snapshot.1` — a guaranteed
/// mid-run checkpoint, captured without perturbing the run.
fn run_collecting(
    cfg: &RunConfig,
    engine: &NativeEngine,
    snapshot: Option<(&Path, &Path)>,
) -> (RunResult, Vec<RunEvent>) {
    let events: Rc<RefCell<Vec<RunEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink = events.clone();
    let mut s = Session::new(cfg, engine).unwrap();
    if let Some((live, _)) = snapshot {
        s.set_checkpoint(1, live);
    }
    let copy = snapshot.map(|(live, aside)| (live.to_path_buf(), aside.to_path_buf()));
    s.observe(from_fn(move |ev: &RunEvent| {
        if let (Some((live, aside)), RunEvent::GlobalUpdate { point }) = (&copy, ev) {
            if point.updates >= 2 && live.exists() && !aside.exists() {
                std::fs::copy(live, aside).unwrap();
            }
        }
        sink.borrow_mut().push(ev.clone());
    }));
    let r = s.run().unwrap();
    let ev = events.borrow().clone();
    (r, ev)
}

/// Resume from a checkpoint document and run to completion, collecting
/// the resumed event stream.
fn resume_collecting(doc: &Json, engine: &NativeEngine) -> (RunResult, Vec<RunEvent>) {
    let events: Rc<RefCell<Vec<RunEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink = events.clone();
    let mut s = Session::resume(doc, engine).unwrap();
    s.observe(from_fn(move |ev: &RunEvent| sink.borrow_mut().push(ev.clone())));
    let r = s.run().unwrap();
    let ev = events.borrow().clone();
    (r, ev)
}

/// Bit-for-bit `RunResult` equality (f64 compared through `to_bits`).
fn assert_result_bits(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(
        a.final_metric.to_bits(),
        b.final_metric.to_bits(),
        "{what}: final_metric {} vs {}",
        a.final_metric,
        b.final_metric
    );
    assert_eq!(a.total_updates, b.total_updates, "{what}: total_updates");
    assert_eq!(a.wall_ms.to_bits(), b.wall_ms.to_bits(), "{what}: wall_ms");
    assert_eq!(
        a.mean_spent.to_bits(),
        b.mean_spent.to_bits(),
        "{what}: mean_spent"
    );
    assert_eq!(a.tau_histogram, b.tau_histogram, "{what}: tau_histogram");
    assert_eq!(a.retired_edges, b.retired_edges, "{what}: retired_edges");
    assert_eq!(a.n_edges, b.n_edges, "{what}: n_edges");
    assert_eq!(a.trace.len(), b.trace.len(), "{what}: trace length");
    for (i, (pa, pb)) in a.trace.iter().zip(&b.trace).enumerate() {
        assert_eq!(
            pa.wall_ms.to_bits(),
            pb.wall_ms.to_bits(),
            "{what}: trace[{i}].wall_ms"
        );
        assert_eq!(
            pa.mean_spent.to_bits(),
            pb.mean_spent.to_bits(),
            "{what}: trace[{i}].mean_spent"
        );
        assert_eq!(pa.updates, pb.updates, "{what}: trace[{i}].updates");
        assert_eq!(
            pa.metric.to_bits(),
            pb.metric.to_bits(),
            "{what}: trace[{i}].metric"
        );
    }
}

/// One cell of the equality matrix: baseline, checkpointed baseline,
/// mid-run resume, and the snapshot JSON round-trip.
fn check_cell(task: &str, strategy: &str) {
    let engine = NativeEngine::default();
    let c = cfg(task, strategy);
    let what = format!("{task}/{strategy}");
    let dir = scratch(&what);
    let live = dir.join("checkpoint.json");
    let aside = dir.join("midrun.json");

    // 1. Ground truth, no checkpointing anywhere near it.
    let (r0, ev0) = run_collecting(&c, &engine, None);
    assert!(
        r0.total_updates >= 4,
        "{what}: run too short to checkpoint mid-way ({} updates)",
        r0.total_updates
    );

    // 2. Checkpointing is a pure side effect.
    let (r1, ev1) = run_collecting(&c, &engine, Some((&live, &aside)));
    assert_result_bits(&r0, &r1, &format!("{what}: checkpointing perturbed the run"));
    assert_eq!(
        ev0, ev1,
        "{what}: checkpointing changed the event stream"
    );

    // 3. Restart equals uninterrupted, from a genuinely mid-run snapshot.
    assert!(aside.exists(), "{what}: no mid-run snapshot was captured");
    let doc = checkpoint::load(&aside).unwrap();
    let (r2, ev2) = resume_collecting(&doc, &engine);
    assert_result_bits(&r0, &r2, &format!("{what}: resumed run diverged"));
    assert!(
        !ev2.is_empty() && ev2.len() < ev0.len(),
        "{what}: resume replayed {} of {} events — not a mid-run cut",
        ev2.len(),
        ev0.len()
    );
    assert_eq!(
        &ev0[ev0.len() - ev2.len()..],
        &ev2[..],
        "{what}: resumed event stream is not the baseline's suffix"
    );

    // 4. Resume + re-checkpoint at the same round is the identity.
    let mut s = Session::resume(&doc, &engine).unwrap();
    let run_cfg = s.cfg().clone();
    let mut mode = mode_for(&run_cfg);
    mode.restore(&mut s, doc.get("mode").unwrap()).unwrap();
    let doc2 = s.checkpoint(mode.as_ref()).unwrap();
    assert_eq!(
        doc.to_string(),
        doc2.to_string(),
        "{what}: checkpoint JSON does not round-trip through resume"
    );

    std::fs::remove_dir_all(&dir).ok();
}

/// The strategy axis for one collaboration manner. `ac-sync` is
/// barrier-only and appears in the sync row alone.
fn strategies(mode: &str) -> Vec<String> {
    let mut v = vec![
        format!("ol4el:mode={mode}"),
        format!("fixed-i:mode={mode}"),
        format!("greedy-budget:mode={mode}"),
    ];
    if mode == "sync" {
        v.push("ac-sync".to_string());
    }
    v
}

fn check_task(task: &str) {
    for mode in ["sync", "async"] {
        for strategy in strategies(mode) {
            check_cell(task, &strategy);
        }
    }
}

#[test]
fn restart_equals_uninterrupted_svm() {
    check_task("svm");
}

#[test]
fn restart_equals_uninterrupted_kmeans() {
    check_task("kmeans");
}

#[test]
fn restart_equals_uninterrupted_logreg() {
    check_task("logreg");
}

#[test]
fn restart_equals_uninterrupted_gmm() {
    check_task("gmm");
}

#[test]
fn resume_refuses_a_version_from_the_future() {
    let engine = NativeEngine::default();
    let c = cfg("svm", "ol4el");
    let dir = scratch("future-version");
    let live = dir.join("checkpoint.json");
    let aside = dir.join("midrun.json");
    run_collecting(&c, &engine, Some((&live, &aside)));
    let mut doc = checkpoint::load(&aside).unwrap();
    if let Json::Obj(m) = &mut doc {
        m.insert("version".into(), Json::num(999.0));
    }
    let err = Session::resume(&doc, &engine).unwrap_err().to_string();
    assert!(err.contains("version"), "unhelpful version error: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_refuses_a_differently_sized_fleet() {
    let engine = NativeEngine::default();
    let c = cfg("svm", "ol4el");
    let dir = scratch("fleet-size");
    let live = dir.join("checkpoint.json");
    let aside = dir.join("midrun.json");
    run_collecting(&c, &engine, Some((&live, &aside)));
    let mut doc = checkpoint::load(&aside).unwrap();
    // Rewrite the embedded config to a bigger fleet: the structural
    // state (per-edge entries, slowdowns) no longer covers it.
    let bigger = RunConfig { n_edges: 5, ..cfg("svm", "ol4el") };
    if let Json::Obj(m) = &mut doc {
        m.insert("config".into(), bigger.to_json());
    }
    assert!(Session::resume(&doc, &engine).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
