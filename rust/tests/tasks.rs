//! The open-task-layer acceptance tests.
//!
//! 1. **Legacy regression guard** — the migrated `Learner`-based svm and
//!    kmeans paths must reproduce the pre-refactor behavior. The learner
//!    transcribes the legacy numerics line for line (same generator
//!    structs, same RNG consumption order in `World::build`, same step /
//!    eval math); with no pre-refactor binary to diff against in the
//!    offline image, the guard asserts what is mechanically checkable:
//!    the learner's dispatch is bit-equal to direct calls into the
//!    reference math on identical buffers, and fixed-seed event streams
//!    are exactly reproducible (sync + async, native engine).
//! 2. **The API is actually open** — logistic regression and the GMM run
//!    end-to-end through sessions, suites and the sharded fleet
//!    simulator, and a task registered at runtime from *outside* the
//!    crate (this test file) trains end-to-end with a custom aggregation
//!    rule.

use std::sync::{Arc, Mutex};

use ol4el::config::RunConfig;
use ol4el::coordinator::{self, find_outcome, observer, ExperimentSuite, RunEvent, Session};
use ol4el::strategy::StrategySpec;
use ol4el::data::Dataset;
use ol4el::edge::Hyper;
use ol4el::engine::native::NativeEngine;
use ol4el::engine::ComputeEngine;
use ol4el::engine::EngineOps as _;
use ol4el::model::{self, Learner, StepOut, TaskFactory, TaskSpec};
use ol4el::net::FleetSim;
use ol4el::util::rng::Rng;

fn cfg(task: TaskSpec, strategy: StrategySpec) -> RunConfig {
    RunConfig {
        task,
        strategy,
        n_edges: 3,
        budget: 1500.0,
        data_n: 4000,
        seed: 11,
        ..Default::default()
    }
}

/// Capture a run's full event stream as Debug strings (f64s print with
/// shortest-round-trip precision, so string equality IS bit-for-bit
/// equality of every payload).
fn event_stream(c: &RunConfig) -> (Vec<String>, coordinator::RunResult) {
    let engine = NativeEngine::default();
    let seen = Arc::new(Mutex::new(Vec::new()));
    let sink = seen.clone();
    let mut session = Session::new(c, &engine).unwrap();
    session.observe(observer::from_fn(move |ev: &RunEvent| {
        sink.lock().unwrap().push(format!("{ev:?}"));
    }));
    let result = session.run().unwrap();
    let stream = seen.lock().unwrap().clone();
    (stream, result)
}

// ---------------------------------------------------------------------------
// 1. Legacy regression guard
// ---------------------------------------------------------------------------

#[test]
fn svm_learner_step_is_bit_equal_to_reference_math() {
    let engine = NativeEngine::default();
    let learner = TaskSpec::svm().learner();
    let mut rng = Rng::new(5);
    let ds = learner.synth(2000, 2.5, &mut rng);
    let n = learner.batch();
    let x = ds.x[..n * ds.d].to_vec();
    let y = ds.y[..n].to_vec();
    let hyper = Hyper::default();

    let mut p_learner = learner.init_params(&ds, &mut rng);
    let mut p_direct = p_learner.clone();
    for _ in 0..5 {
        let out = learner
            .local_step(&engine, &mut p_learner, &x, &y, &hyper)
            .unwrap();
        let loss = ol4el::model::svm::step(
            &mut p_direct,
            &x,
            &y,
            &ol4el::model::svm::SvmSpec {
                d: 59,
                c: 8,
                lr: hyper.lr,
                reg: hyper.reg,
            },
        );
        assert_eq!(out.signal, loss as f64, "loss diverged from reference");
        assert_eq!(p_learner, p_direct, "params diverged from reference");
    }
    // Eval dispatch: accuracy == metrics::accuracy over the reference eval.
    let (correct, _) = ol4el::model::svm::eval(
        &p_learner,
        &x,
        &y,
        &ol4el::model::svm::SvmSpec {
            d: 59,
            c: 8,
            lr: 0.0,
            reg: 0.0,
        },
    );
    let m = learner.evaluate(&engine, &p_learner, &x, &y).unwrap();
    assert_eq!(m, ol4el::metrics::accuracy(correct, n));
}

#[test]
fn kmeans_learner_step_is_bit_equal_to_reference_math() {
    let engine = NativeEngine::default();
    let learner = TaskSpec::kmeans().learner();
    let mut rng = Rng::new(6);
    let ds = learner.synth(2000, 4.0, &mut rng);
    let n = learner.batch();
    let x = ds.x[..n * ds.d].to_vec();
    let y = ds.y[..n].to_vec();
    let hyper = Hyper::default();
    let spec = ol4el::model::kmeans::KmeansSpec { k: 3, d: 16 };

    let mut p_learner = learner.init_params(&ds, &mut rng);
    let mut p_direct = p_learner.clone();
    for _ in 0..5 {
        let out = learner
            .local_step(&engine, &mut p_learner, &x, &y, &hyper)
            .unwrap();
        // The legacy edge loop verbatim: E-step stats + damped M-step.
        let (sums, counts, inertia) = ol4el::model::kmeans::stats(&p_direct, &x, &spec);
        let eta = (hyper.lr as f64 * 0.75).clamp(0.0, 1.0) as f32;
        let mut target = p_direct.clone();
        ol4el::model::kmeans::mstep(&mut target, &sums, &counts, &spec);
        for (c, t) in p_direct.iter_mut().zip(&target) {
            *c += eta * (*t - *c);
        }
        assert_eq!(out.signal, inertia as f64, "inertia diverged");
        assert_eq!(p_learner, p_direct, "centers diverged from reference");
    }
    let (assignments, _) = ol4el::model::kmeans::assign(&p_learner, &x, &spec);
    let m = learner.evaluate(&engine, &p_learner, &x, &y).unwrap();
    assert_eq!(m, ol4el::metrics::clustering_f1(&assignments, &y, 3));
}

#[test]
fn fixed_seed_event_streams_reproduce_exactly() {
    // The migrated paths stay deterministic to the bit: two identical
    // runs emit identical event streams for both manners and both legacy
    // tasks (the trace/TracePoint payloads ride inside the stream).
    for task in [TaskSpec::svm(), TaskSpec::kmeans()] {
        for strategy in [StrategySpec::ol4el_sync(), StrategySpec::ol4el_async()] {
            let c = cfg(task.clone(), strategy.clone());
            let (s1, r1) = event_stream(&c);
            let (s2, r2) = event_stream(&c);
            assert_eq!(s1.len(), s2.len(), "{task}/{strategy}");
            for (k, (a, b)) in s1.iter().zip(&s2).enumerate() {
                assert_eq!(a, b, "{task}/{strategy}: event {k} diverged");
            }
            assert_eq!(r1.final_metric, r2.final_metric);
            assert_eq!(r1.trace, r2.trace);
            assert_eq!(r1.tau_histogram, r2.tau_histogram);
        }
    }
}

// ---------------------------------------------------------------------------
// 2. The new tasks run end to end
// ---------------------------------------------------------------------------

#[test]
fn logreg_trains_end_to_end_both_manners() {
    let engine = NativeEngine::default();
    for strategy in [StrategySpec::ol4el_sync(), StrategySpec::ol4el_async()] {
        let mut c = cfg(TaskSpec::parse("logreg:d=59:c=8").unwrap(), strategy.clone());
        c.budget = 2500.0;
        c = c.with_paper_utility();
        let r = coordinator::run(&c, &engine).unwrap();
        let first = r.trace.first().unwrap().metric;
        assert!(r.total_updates > 0, "{strategy}");
        assert!(
            r.final_metric > first + 0.15,
            "{strategy}: logreg failed to learn: {first:.3} -> {:.3}",
            r.final_metric
        );
    }
}

#[test]
fn gmm_trains_end_to_end_both_manners() {
    let engine = NativeEngine::default();
    for strategy in [StrategySpec::ol4el_sync(), StrategySpec::ol4el_async()] {
        // Cluster recovery has seed variance (init + matching): assert on
        // the two-seed mean, like the kmeans integration test.
        let mut mean = 0.0;
        for seed in [3, 4] {
            let mut c = cfg(TaskSpec::parse("gmm:k=3").unwrap(), strategy.clone());
            c.budget = 5000.0;
            c.seed = seed;
            mean += coordinator::run(&c, &engine).unwrap().final_metric / 2.0;
        }
        assert!(mean > 0.6, "{strategy}: weak GMM clustering, mean F1 {mean:.3}");
    }
}

#[test]
fn suites_sweep_the_new_tasks() {
    let base = RunConfig {
        data_n: 3000,
        budget: 600.0,
        n_edges: 3,
        seed: 1,
        ..Default::default()
    };
    let suite = ExperimentSuite::new("tasks", base)
        .tasks([
            TaskSpec::svm(),
            TaskSpec::logreg(),
            TaskSpec::parse("gmm:k=3").unwrap(),
        ])
        .strategies([StrategySpec::ol4el_async()]);
    let outs = suite.run_native().unwrap();
    assert_eq!(outs.len(), 3);
    for out in &outs {
        assert!(
            out.agg.metric.mean() > 0.0,
            "{}: empty metric",
            out.spec.task
        );
    }
    let ol4el = StrategySpec::ol4el_async();
    assert!(find_outcome(&outs, &TaskSpec::logreg(), &ol4el, 3, 1.0).is_some());
    assert!(find_outcome(&outs, &TaskSpec::gmm(), &ol4el, 3, 1.0).is_some());
}

#[test]
fn fleet_carries_new_tasks_and_sharding_stays_exact() {
    // One 1-vs-4-shard fleet case per new task: the engine-free protocol
    // simulator accepts any registered task's config and the sharding
    // determinism contract holds bit for bit.
    for task in [TaskSpec::logreg(), TaskSpec::parse("gmm:k=3").unwrap()] {
        let c = RunConfig {
            task,
            n_edges: 120,
            hetero: 4.0,
            budget: 1200.0,
            eval_every: 50,
            data_n: 20_000,
            network: ol4el::net::NetworkSpec::parse("uniform:2:10,drop:0.02").unwrap(),
            seed: 9,
            ..Default::default()
        };
        let one = FleetSim::new(c.clone()).unwrap().shards(1).run().unwrap();
        let four = FleetSim::new(c.clone()).unwrap().shards(4).run().unwrap();
        assert!(one.updates > 0, "{}: fleet made no updates", c.task);
        assert_eq!(one.updates, four.updates, "{}", c.task);
        assert_eq!(one.wall_ms, four.wall_ms, "{}", c.task);
        assert_eq!(one.mean_spent, four.mean_spent, "{}", c.task);
        assert_eq!(one.messages_sent, four.messages_sent, "{}", c.task);
    }
}

// ---------------------------------------------------------------------------
// 3. Openness: a task registered at runtime, from outside the crate
// ---------------------------------------------------------------------------

/// A deliberately minimal 1-D learner: the model is `[location]`, a step
/// moves it toward the batch mean, the metric is closeness to the data
/// mean. Its aggregation rule is NOT the default (max instead of mean) to
/// prove the hook is honored.
#[derive(Clone, Copy, Debug, Default)]
struct ToyMean;

impl Learner for ToyMean {
    fn name(&self) -> &'static str {
        "toymean"
    }
    fn spec(&self) -> String {
        "toymean".to_string()
    }
    fn supervised(&self) -> bool {
        false
    }
    fn metric_name(&self) -> &'static str {
        "closeness"
    }
    fn param_len(&self) -> usize {
        1
    }
    fn batch(&self) -> usize {
        16
    }
    fn eval_batch(&self) -> usize {
        64
    }
    fn synth(&self, n: usize, _separation: f64, rng: &mut Rng) -> Dataset {
        let x: Vec<f32> = (0..n).map(|_| 3.0 + rng.normal() as f32).collect();
        let y = vec![0i32; n];
        Dataset::new(x, y, 1)
    }
    fn init_params(&self, _train: &Dataset, _rng: &mut Rng) -> Vec<f32> {
        vec![0.0]
    }
    fn local_step(
        &self,
        engine: &dyn ComputeEngine,
        params: &mut [f32],
        x: &[f32],
        _y: &[i32],
        hyper: &Hyper,
    ) -> anyhow::Result<StepOut> {
        let mean = engine.ops().reduce_sum(x) as f32 / x.len() as f32;
        let err = mean - params[0];
        params[0] += hyper.lr * err;
        Ok(StepOut {
            signal: (err * err) as f64,
        })
    }
    fn evaluate(
        &self,
        engine: &dyn ComputeEngine,
        params: &[f32],
        x: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<f64> {
        let mean = engine.ops().reduce_sum(x) as f32 / x.len() as f32;
        Ok((1.0 / (1.0 + (mean - params[0]).abs() as f64)).clamp(0.0, 1.0))
    }
    fn aggregate(&self, locals: &[(&[f32], f64)]) -> Vec<f32> {
        // Max-merge: observable difference from the default averaging.
        vec![locals
            .iter()
            .map(|(p, _)| p[0])
            .fold(f32::NEG_INFINITY, f32::max)]
    }
    fn clone_box(&self) -> Box<dyn Learner> {
        Box::new(*self)
    }
}

#[test]
fn runtime_registered_task_runs_end_to_end() {
    model::register(TaskFactory {
        name: "toymean",
        about: "test-only 1-D mean tracker",
        build: |p| {
            p.finish("toymean")?;
            Ok(Box::new(ToyMean))
        },
    })
    .unwrap();

    // The spec now parses everywhere a task name does...
    let spec = TaskSpec::parse("toymean").unwrap();
    assert_eq!(spec.name(), "toymean");
    // ...survives the JSON wire format...
    let mut c = cfg(spec, StrategySpec::ol4el_sync());
    c.data_n = 1000;
    c.budget = 800.0;
    c.hyper.lr = 0.5; // the toy tracker needs a brisk step to converge
    let back = RunConfig::from_json(&c.to_json()).unwrap();
    assert_eq!(back.task, c.task);
    // ...and trains end-to-end through the standard session machinery,
    // exercising the custom aggregation rule via the sync barrier.
    let engine = NativeEngine::default();
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.total_updates > 0);
    assert!(
        r.final_metric > 0.5,
        "toy task failed to track the mean: {}",
        r.final_metric
    );

    // Unknown-parameter rejection flows through the factory's finish().
    assert!(TaskSpec::parse("toymean:k=2").is_err());
}

#[test]
fn builder_surfaces_dataset_sizing_errors() {
    // Satellite check at the builder surface (validate() unit tests live
    // in config.rs): a bad eval split is a typed error before any run.
    let err = ol4el::coordinator::Experiment::builder()
        .task(TaskSpec::svm())
        .data_n(512)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("eval split"), "{err}");

    let err = ol4el::coordinator::Experiment::builder()
        .task(TaskSpec::svm())
        .data_n(515)
        .edges(10)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("too few to cover"), "{err}");
}
