//! Integration tests: whole runs through the public API on the native
//! engine, checking the paper's qualitative claims hold end to end.

use ol4el::config::{Algo, RunConfig};
use ol4el::coordinator::{self, observer, Experiment, RunEvent};
use ol4el::engine::native::NativeEngine;
use ol4el::model::Task;
use std::sync::{Arc, Mutex};

fn cfg(task: Task, algo: Algo) -> RunConfig {
    RunConfig {
        task,
        algo,
        n_edges: 3,
        hetero: 1.0,
        budget: 2000.0,
        data_n: 5000,
        seed: 3,
        ..Default::default()
    }
    .with_paper_utility()
}

#[test]
fn all_algorithms_learn_svm() {
    let engine = NativeEngine::default();
    for algo in [Algo::Ol4elSync, Algo::Ol4elAsync, Algo::AcSync, Algo::FixedI] {
        let r = coordinator::run(&cfg(Task::Svm, algo), &engine).unwrap();
        let first = r.trace.first().unwrap().metric;
        assert!(
            r.final_metric > first + 0.15,
            "{} failed to learn: {first:.3} -> {:.3}",
            algo.name(),
            r.final_metric
        );
        assert!(r.total_updates > 0, "{}", algo.name());
    }
}

#[test]
fn all_algorithms_learn_kmeans() {
    // K=3 cluster recovery has real seed variance (init + matching), so
    // assert on the two-seed mean per algorithm.
    let engine = NativeEngine::default();
    for algo in [Algo::Ol4elSync, Algo::Ol4elAsync, Algo::AcSync, Algo::FixedI] {
        let mut mean = 0.0;
        for seed in [3, 4] {
            let mut c = cfg(Task::Kmeans, algo);
            c.budget = 5000.0;
            c.seed = seed;
            mean += coordinator::run(&c, &engine).unwrap().final_metric / 2.0;
        }
        assert!(
            mean > 0.6,
            "{} weak clustering: mean F1 {:.3}",
            algo.name(),
            mean
        );
    }
}

#[test]
fn runs_are_reproducible_across_algorithms() {
    let engine = NativeEngine::default();
    for algo in [Algo::Ol4elSync, Algo::Ol4elAsync, Algo::AcSync, Algo::FixedI] {
        let c = cfg(Task::Svm, algo);
        let a = coordinator::run(&c, &engine).unwrap();
        let b = coordinator::run(&c, &engine).unwrap();
        assert_eq!(a.final_metric, b.final_metric, "{}", algo.name());
        assert_eq!(a.total_updates, b.total_updates, "{}", algo.name());
        assert_eq!(a.mean_spent, b.mean_spent, "{}", algo.name());
    }
}

#[test]
fn different_seeds_give_different_runs() {
    let engine = NativeEngine::default();
    let mut c = cfg(Task::Svm, Algo::Ol4elAsync);
    let a = coordinator::run(&c, &engine).unwrap();
    c.seed = 4;
    let b = coordinator::run(&c, &engine).unwrap();
    assert_ne!(
        (a.final_metric, a.total_updates),
        (b.final_metric, b.total_updates)
    );
}

#[test]
fn paper_claim_async_beats_sync_at_high_heterogeneity() {
    // Fig. 3's crossover: at high H the async pattern dominates.
    let engine = NativeEngine::default();
    let mut acc_async = 0.0;
    let mut acc_sync = 0.0;
    for seed in [1, 2, 3] {
        let mut ca = cfg(Task::Svm, Algo::Ol4elAsync);
        ca.hetero = 10.0;
        ca.budget = 3000.0;
        ca.seed = seed;
        let mut cs = ca.clone();
        cs.algo = Algo::Ol4elSync;
        acc_async += coordinator::run(&ca, &engine).unwrap().final_metric;
        acc_sync += coordinator::run(&cs, &engine).unwrap().final_metric;
    }
    assert!(
        acc_async > acc_sync,
        "async {acc_async:.3} should beat sync {acc_sync:.3} at H=10"
    );
}

#[test]
fn paper_claim_accuracy_rises_with_budget() {
    // Fig. 4's monotone trade-off: more resource -> better model.
    let engine = NativeEngine::default();
    let mut small = cfg(Task::Svm, Algo::Ol4elAsync);
    small.budget = 500.0;
    let mut large = small.clone();
    large.budget = 4000.0;
    let r_small = coordinator::run(&small, &engine).unwrap();
    let r_large = coordinator::run(&large, &engine).unwrap();
    assert!(
        r_large.final_metric > r_small.final_metric,
        "budget 4000 ({:.3}) should beat 500 ({:.3})",
        r_large.final_metric,
        r_small.final_metric
    );
}

#[test]
fn trace_is_monotone_in_time_and_consumption() {
    let engine = NativeEngine::default();
    for algo in [Algo::Ol4elSync, Algo::Ol4elAsync] {
        let r = coordinator::run(&cfg(Task::Svm, algo), &engine).unwrap();
        for w in r.trace.windows(2) {
            assert!(w[1].wall_ms >= w[0].wall_ms, "{}", algo.name());
            assert!(w[1].mean_spent >= w[0].mean_spent, "{}", algo.name());
            assert!(w[1].updates >= w[0].updates, "{}", algo.name());
        }
    }
}

#[test]
fn variable_cost_mode_runs_with_ucb_bv() {
    let engine = NativeEngine::default();
    let mut c = cfg(Task::Svm, Algo::Ol4elAsync);
    c.cost.mode = ol4el::sim::cost::CostMode::Variable { cv: 0.3 };
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.total_updates > 0);
    assert!(r.final_metric > 0.3);
}

#[test]
fn label_skew_partition_still_learns() {
    let engine = NativeEngine::default();
    let mut c = cfg(Task::Svm, Algo::Ol4elAsync);
    c.partition = ol4el::config::PartitionKind::LabelSkew { alpha: 0.3 };
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.final_metric > 0.4, "skewed F1 {}", r.final_metric);
}

#[test]
fn single_edge_fleet_works() {
    let engine = NativeEngine::default();
    let mut c = cfg(Task::Kmeans, Algo::Ol4elAsync);
    c.n_edges = 1;
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.total_updates > 0);
    assert_eq!(r.n_edges, 1);
}

#[test]
fn tiny_budget_retires_without_updates() {
    let engine = NativeEngine::default();
    let mut c = cfg(Task::Svm, Algo::Ol4elAsync);
    c.budget = 1.0; // cheaper than any arm
    let r = coordinator::run(&c, &engine).unwrap();
    assert_eq!(r.total_updates, 0);
    assert_eq!(r.retired_edges, 3);
    assert_eq!(r.mean_spent, 0.0);
}

#[test]
fn config_json_roundtrip_through_run() {
    let engine = NativeEngine::default();
    let c = cfg(Task::Svm, Algo::Ol4elSync);
    let j = c.to_json();
    let c2 = RunConfig::from_json(&j).unwrap();
    let a = coordinator::run(&c, &engine).unwrap();
    let b = coordinator::run(&c2, &engine).unwrap();
    assert_eq!(a.final_metric, b.final_metric);
}

#[test]
fn observer_global_updates_mirror_trace_bit_for_bit() {
    // Acceptance criterion of the Session redesign: an Observer registered
    // via the builder receives exactly the GlobalUpdate stream that
    // RunResult::trace is rebuilt from — bit-for-bit, both manners.
    let engine = NativeEngine::default();
    for algo in [Algo::Ol4elSync, Algo::Ol4elAsync, Algo::AcSync, Algo::FixedI] {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink = seen.clone();
        let result = Experiment::builder()
            .task(Task::Svm)
            .algo(algo)
            .edges(3)
            .budget(2000.0)
            .data_n(5000)
            .seed(3)
            .paper_regime()
            .observe(observer::from_fn(move |ev: &RunEvent| {
                if let RunEvent::GlobalUpdate { point } = ev {
                    sink.lock().unwrap().push(point.clone());
                }
            }))
            .run(&engine)
            .unwrap();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), result.trace.len(), "{}", algo.name());
        for (streamed, recorded) in seen.iter().zip(&result.trace) {
            assert_eq!(streamed, recorded, "{}", algo.name());
        }
    }
}

#[test]
fn experiment_builder_reproduces_wire_config_runs() {
    // The builder is a front door over the same wire format: identical
    // settings must give identical runs (same RNG schedule end to end).
    let engine = NativeEngine::default();
    let wire = cfg(Task::Svm, Algo::Ol4elAsync);
    let a = coordinator::run(&wire, &engine).unwrap();
    let b = Experiment::builder()
        .task(Task::Svm)
        .algo(Algo::Ol4elAsync)
        .edges(3)
        .hetero(1.0)
        .budget(2000.0)
        .data_n(5000)
        .seed(3)
        .paper_regime()
        .run(&engine)
        .unwrap();
    assert_eq!(a.final_metric, b.final_metric);
    assert_eq!(a.total_updates, b.total_updates);
    assert_eq!(a.tau_histogram, b.tau_histogram);
    assert_eq!(a.trace.len(), b.trace.len());
}

#[test]
fn finished_event_matches_run_result() {
    let engine = NativeEngine::default();
    let summary = Arc::new(Mutex::new(None));
    let sink = summary.clone();
    let result = Experiment::builder()
        .task(Task::Kmeans)
        .algo(Algo::Ol4elAsync)
        .edges(3)
        .budget(1500.0)
        .data_n(4000)
        .seed(9)
        .observe(observer::from_fn(move |ev: &RunEvent| {
            if let RunEvent::Finished {
                wall_ms,
                updates,
                final_metric,
            } = ev
            {
                *sink.lock().unwrap() = Some((*wall_ms, *updates, *final_metric));
            }
        }))
        .run(&engine)
        .unwrap();
    let (wall_ms, updates, final_metric) = summary.lock().unwrap().unwrap();
    assert_eq!(wall_ms, result.wall_ms);
    assert_eq!(updates, result.total_updates);
    assert_eq!(final_metric, result.final_metric);
}
