//! Integration tests: whole runs through the public API on the native
//! engine, checking the paper's qualitative claims hold end to end.

use ol4el::config::RunConfig;
use ol4el::coordinator::{self, observer, Experiment, RunEvent, Session};
use ol4el::engine::native::NativeEngine;
use ol4el::harness::paper_strategies;
use ol4el::model::TaskSpec;
use ol4el::net::{ChurnSpec, FleetSim, NetAsyncMerge, NetSyncBarrier, NetworkSpec};
use ol4el::strategy::StrategySpec;
use std::sync::{Arc, Mutex};

fn cfg(task: TaskSpec, strategy: StrategySpec) -> RunConfig {
    RunConfig {
        task,
        strategy,
        n_edges: 3,
        hetero: 1.0,
        budget: 2000.0,
        data_n: 5000,
        seed: 3,
        ..Default::default()
    }
    .with_paper_utility()
}

#[test]
fn all_algorithms_learn_svm() {
    let engine = NativeEngine::default();
    for strategy in paper_strategies() {
        let r = coordinator::run(&cfg(TaskSpec::svm(), strategy.clone()), &engine).unwrap();
        let first = r.trace.first().unwrap().metric;
        assert!(
            r.final_metric > first + 0.15,
            "{strategy} failed to learn: {first:.3} -> {:.3}",
            r.final_metric
        );
        assert!(r.total_updates > 0, "{strategy}");
    }
}

#[test]
fn all_algorithms_learn_kmeans() {
    // K=3 cluster recovery has real seed variance (init + matching), so
    // assert on the two-seed mean per algorithm.
    let engine = NativeEngine::default();
    for strategy in paper_strategies() {
        let mut mean = 0.0;
        for seed in [3, 4] {
            let mut c = cfg(TaskSpec::kmeans(), strategy.clone());
            c.budget = 5000.0;
            c.seed = seed;
            mean += coordinator::run(&c, &engine).unwrap().final_metric / 2.0;
        }
        assert!(mean > 0.6, "{strategy} weak clustering: mean F1 {mean:.3}");
    }
}

#[test]
fn runs_are_reproducible_across_algorithms() {
    let engine = NativeEngine::default();
    for strategy in paper_strategies() {
        let c = cfg(TaskSpec::svm(), strategy.clone());
        let a = coordinator::run(&c, &engine).unwrap();
        let b = coordinator::run(&c, &engine).unwrap();
        assert_eq!(a.final_metric, b.final_metric, "{strategy}");
        assert_eq!(a.total_updates, b.total_updates, "{strategy}");
        assert_eq!(a.mean_spent, b.mean_spent, "{strategy}");
    }
}

#[test]
fn different_seeds_give_different_runs() {
    let engine = NativeEngine::default();
    let mut c = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
    let a = coordinator::run(&c, &engine).unwrap();
    c.seed = 4;
    let b = coordinator::run(&c, &engine).unwrap();
    assert_ne!(
        (a.final_metric, a.total_updates),
        (b.final_metric, b.total_updates)
    );
}

#[test]
fn paper_claim_async_beats_sync_at_high_heterogeneity() {
    // Fig. 3's crossover: at high H the async pattern dominates.
    let engine = NativeEngine::default();
    let mut acc_async = 0.0;
    let mut acc_sync = 0.0;
    for seed in [1, 2, 3] {
        let mut ca = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
        ca.hetero = 10.0;
        ca.budget = 3000.0;
        ca.seed = seed;
        let mut cs = ca.clone();
        cs.strategy = StrategySpec::ol4el_sync();
        acc_async += coordinator::run(&ca, &engine).unwrap().final_metric;
        acc_sync += coordinator::run(&cs, &engine).unwrap().final_metric;
    }
    assert!(
        acc_async > acc_sync,
        "async {acc_async:.3} should beat sync {acc_sync:.3} at H=10"
    );
}

#[test]
fn paper_claim_accuracy_rises_with_budget() {
    // Fig. 4's monotone trade-off: more resource -> better model.
    let engine = NativeEngine::default();
    let mut small = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
    small.budget = 500.0;
    let mut large = small.clone();
    large.budget = 4000.0;
    let r_small = coordinator::run(&small, &engine).unwrap();
    let r_large = coordinator::run(&large, &engine).unwrap();
    assert!(
        r_large.final_metric > r_small.final_metric,
        "budget 4000 ({:.3}) should beat 500 ({:.3})",
        r_large.final_metric,
        r_small.final_metric
    );
}

#[test]
fn trace_is_monotone_in_time_and_consumption() {
    let engine = NativeEngine::default();
    for strategy in [StrategySpec::ol4el_sync(), StrategySpec::ol4el_async()] {
        let r = coordinator::run(&cfg(TaskSpec::svm(), strategy.clone()), &engine).unwrap();
        for w in r.trace.windows(2) {
            assert!(w[1].wall_ms >= w[0].wall_ms, "{strategy}");
            assert!(w[1].mean_spent >= w[0].mean_spent, "{strategy}");
            assert!(w[1].updates >= w[0].updates, "{strategy}");
        }
    }
}

#[test]
fn variable_cost_mode_runs_with_ucb_bv() {
    let engine = NativeEngine::default();
    let mut c = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
    c.cost.mode = ol4el::sim::cost::CostMode::Variable { cv: 0.3 };
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.total_updates > 0);
    assert!(r.final_metric > 0.3);
}

#[test]
fn label_skew_partition_still_learns() {
    let engine = NativeEngine::default();
    let mut c = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
    c.partition = ol4el::config::PartitionKind::LabelSkew { alpha: 0.3 };
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.final_metric > 0.4, "skewed F1 {}", r.final_metric);
}

#[test]
fn single_edge_fleet_works() {
    let engine = NativeEngine::default();
    let mut c = cfg(TaskSpec::kmeans(), StrategySpec::ol4el_async());
    c.n_edges = 1;
    let r = coordinator::run(&c, &engine).unwrap();
    assert!(r.total_updates > 0);
    assert_eq!(r.n_edges, 1);
}

#[test]
fn tiny_budget_retires_without_updates() {
    let engine = NativeEngine::default();
    let mut c = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
    c.budget = 1.0; // cheaper than any arm
    let r = coordinator::run(&c, &engine).unwrap();
    assert_eq!(r.total_updates, 0);
    assert_eq!(r.retired_edges, 3);
    assert_eq!(r.mean_spent, 0.0);
}

#[test]
fn config_json_roundtrip_through_run() {
    let engine = NativeEngine::default();
    let c = cfg(TaskSpec::svm(), StrategySpec::ol4el_sync());
    let j = c.to_json();
    let c2 = RunConfig::from_json(&j).unwrap();
    let a = coordinator::run(&c, &engine).unwrap();
    let b = coordinator::run(&c2, &engine).unwrap();
    assert_eq!(a.final_metric, b.final_metric);
}

#[test]
fn observer_global_updates_mirror_trace_bit_for_bit() {
    // Acceptance criterion of the Session redesign: an Observer registered
    // via the builder receives exactly the GlobalUpdate stream that
    // RunResult::trace is rebuilt from — bit-for-bit, both manners.
    let engine = NativeEngine::default();
    for strategy in paper_strategies() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink = seen.clone();
        let result = Experiment::builder()
            .task(TaskSpec::svm())
            .strategy(strategy.clone())
            .edges(3)
            .budget(2000.0)
            .data_n(5000)
            .seed(3)
            .paper_regime()
            .observe(observer::from_fn(move |ev: &RunEvent| {
                if let RunEvent::GlobalUpdate { point } = ev {
                    sink.lock().unwrap().push(point.clone());
                }
            }))
            .run(&engine)
            .unwrap();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), result.trace.len(), "{strategy}");
        for (streamed, recorded) in seen.iter().zip(&result.trace) {
            assert_eq!(streamed, recorded, "{strategy}");
        }
    }
}

#[test]
fn experiment_builder_reproduces_wire_config_runs() {
    // The builder is a front door over the same wire format: identical
    // settings must give identical runs (same RNG schedule end to end).
    let engine = NativeEngine::default();
    let wire = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
    let a = coordinator::run(&wire, &engine).unwrap();
    let b = Experiment::builder()
        .task(TaskSpec::svm())
        .strategy(StrategySpec::ol4el_async())
        .edges(3)
        .hetero(1.0)
        .budget(2000.0)
        .data_n(5000)
        .seed(3)
        .paper_regime()
        .run(&engine)
        .unwrap();
    assert_eq!(a.final_metric, b.final_metric);
    assert_eq!(a.total_updates, b.total_updates);
    assert_eq!(a.tau_histogram, b.tau_histogram);
    assert_eq!(a.trace.len(), b.trace.len());
}

/// Run `cfg` and capture its full event stream as Debug strings (f64s
/// print with shortest-round-trip precision, so string equality IS
/// bit-for-bit equality of every payload).
fn event_stream(
    cfg: &RunConfig,
    mode: Option<&mut dyn coordinator::CollaborationMode>,
) -> (Vec<String>, coordinator::RunResult) {
    let engine = NativeEngine::default();
    let seen = Arc::new(Mutex::new(Vec::new()));
    let sink = seen.clone();
    let mut session = Session::new(cfg, &engine).unwrap();
    session.observe(observer::from_fn(move |ev: &RunEvent| {
        sink.lock().unwrap().push(format!("{ev:?}"));
    }));
    let result = match mode {
        Some(m) => session.run_with(m).unwrap(),
        None => session.run().unwrap(),
    };
    let stream = seen.lock().unwrap().clone();
    (stream, result)
}

#[test]
fn net_transport_with_ideal_network_reproduces_direct_stream_bit_for_bit() {
    // The net:: acceptance criterion: under NetworkSpec::ideal with no
    // churn, a fixed-seed run routed through SimTransport emits EXACTLY
    // the event stream of the legacy direct-call manners — every
    // RoundStart, LocalReport, GlobalUpdate, EdgeRetired and Finished
    // payload, in order, bit for bit.
    for strategy in paper_strategies() {
        let c = cfg(TaskSpec::svm(), strategy.clone());
        assert!(c.network.is_ideal() && c.churn.is_none());
        let (direct_stream, direct) = event_stream(&c, None);
        let netted = |c: &RunConfig| {
            if !c.sync() {
                let mut m = NetAsyncMerge::new();
                event_stream(c, Some(&mut m))
            } else {
                let mut m = NetSyncBarrier::new();
                event_stream(c, Some(&mut m))
            }
        };
        let (net_stream, net) = netted(&c);
        assert_eq!(
            direct_stream.len(),
            net_stream.len(),
            "{strategy}: stream length"
        );
        for (k, (d, n)) in direct_stream.iter().zip(&net_stream).enumerate() {
            assert_eq!(d, n, "{strategy}: event {k} diverged");
        }
        assert_eq!(direct.final_metric, net.final_metric, "{strategy}");
        assert_eq!(direct.total_updates, net.total_updates, "{strategy}");
        assert_eq!(direct.wall_ms, net.wall_ms, "{strategy}");
        assert_eq!(direct.mean_spent, net.mean_spent, "{strategy}");
        assert_eq!(direct.tau_histogram, net.tau_histogram, "{strategy}");
    }
}

#[test]
fn network_and_churn_survive_the_json_roundtrip() {
    // Satellite of the net:: PR, matching the PR 1 ε-range precedent: the
    // specs ride RunConfig's wire format without loss.
    let mut c = cfg(TaskSpec::svm(), StrategySpec::ol4el_async());
    c.network = NetworkSpec::parse("lognormal:5:0.5,bw:10,drop:0.01,part:100-200").unwrap();
    c.churn = ChurnSpec::parse("poisson:0.01,join:0.05,restart:3000,straggle:0.1:4").unwrap();
    let back = RunConfig::from_json(&c.to_json()).unwrap();
    assert_eq!(back.network, c.network);
    assert_eq!(back.churn, c.churn);
    // Defaults round-trip to defaults.
    let d = RunConfig::default();
    let back = RunConfig::from_json(&d.to_json()).unwrap();
    assert!(back.network.is_ideal());
    assert!(back.churn.is_none());
}

#[test]
fn validate_rejects_what_the_net_wire_grammar_rejects() {
    // A validated config must reload from its own JSON: out-of-range spec
    // values are refused by validate() exactly as parse() refuses them.
    let mut c = RunConfig::default();
    c.network.drop_rate = 1.0; // grammar requires [0, 1)
    assert!(c.validate().is_err());
    c = RunConfig::default();
    c.network.timeout_ms = 0.0;
    assert!(c.validate().is_err());
    c = RunConfig::default();
    c.network.partitions.push((500.0, 100.0));
    assert!(c.validate().is_err());
    c = RunConfig::default();
    c.churn.leave_rate = -1.0;
    assert!(c.validate().is_err());
    c = RunConfig::default();
    c.churn.straggle_factor = 0.5;
    assert!(c.validate().is_err());
    // And the JSON parser refuses malformed specs outright.
    let mut j = RunConfig::default().to_json();
    if let ol4el::util::json::Json::Obj(map) = &mut j {
        map.insert(
            "network".to_string(),
            ol4el::util::json::Json::Str("warp:9".to_string()),
        );
    }
    assert!(RunConfig::from_json(&j).is_err());
}

#[test]
fn fleet_5000_edges_with_latency_and_churn_completes() {
    // Acceptance: a 5000-edge sync+async fleet with lognormal latency and
    // Poisson churn completes inside the CI budget and streams
    // EdgeJoined / EdgeRetired / MessageDropped through the Observer API.
    let base = RunConfig {
        strategy: StrategySpec::ol4el_async(),
        n_edges: 5000,
        hetero: 6.0,
        budget: 1200.0,
        data_n: 20_000,
        eval_every: 1000,
        network: NetworkSpec::parse("lognormal:5:0.5,drop:0.02").unwrap(),
        // join is a FLEET-level rate per virtual second: 10/s over a ~2s
        // run is ~20 expected joins — far from the zero-join flake zone.
        churn: ChurnSpec::parse("poisson:0.05,join:10").unwrap(),
        seed: 17,
        ..Default::default()
    };
    let joined = Arc::new(Mutex::new(0usize));
    let retired = Arc::new(Mutex::new(0usize));
    let dropped = Arc::new(Mutex::new(0usize));
    let (j2, r2, d2) = (joined.clone(), retired.clone(), dropped.clone());
    let r = FleetSim::new(base.clone())
        .unwrap()
        .observe(observer::from_fn(move |ev: &RunEvent| match ev {
            RunEvent::EdgeJoined { .. } => *j2.lock().unwrap() += 1,
            RunEvent::EdgeRetired { .. } => *r2.lock().unwrap() += 1,
            RunEvent::MessageDropped { .. } => *d2.lock().unwrap() += 1,
            _ => {}
        }))
        .run()
        .unwrap();
    assert!(r.updates > 5000, "async updates {}", r.updates);
    assert_eq!(r.n_edges, 5000);
    assert!(*joined.lock().unwrap() > 0, "no EdgeJoined events");
    assert!(*retired.lock().unwrap() > 0, "no EdgeRetired events");
    assert!(*dropped.lock().unwrap() > 0, "no MessageDropped events");

    let mut sync_cfg = base;
    sync_cfg.strategy = StrategySpec::ol4el_sync();
    let rs = FleetSim::new(sync_cfg).unwrap().run().unwrap();
    assert!(rs.updates > 0, "sync fleet made no updates");
    assert!(rs.messages_sent >= rs.updates * 2 * 5000);
}

#[test]
fn finished_event_matches_run_result() {
    let engine = NativeEngine::default();
    let summary = Arc::new(Mutex::new(None));
    let sink = summary.clone();
    let result = Experiment::builder()
        .task(TaskSpec::kmeans())
        .strategy(StrategySpec::ol4el_async())
        .edges(3)
        .budget(1500.0)
        .data_n(4000)
        .seed(9)
        .observe(observer::from_fn(move |ev: &RunEvent| {
            if let RunEvent::Finished {
                wall_ms,
                updates,
                final_metric,
            } = ev
            {
                *sink.lock().unwrap() = Some((*wall_ms, *updates, *final_metric));
            }
        }))
        .run(&engine)
        .unwrap();
    let (wall_ms, updates, final_metric) = summary.lock().unwrap().unwrap();
    assert_eq!(wall_ms, result.wall_ms);
    assert_eq!(updates, result.total_updates);
    assert_eq!(final_metric, result.final_metric);
}
