//! The sharded fleet simulator's determinism contract: a sharded run is
//! **bit-for-bit identical** to the single-threaded run at any shard
//! count. These tests capture the full `RunEvent` stream (every payload
//! f64 included — `RunEvent: PartialEq` compares exact bits) and the
//! protocol-level report fields across shard counts.

use std::cell::RefCell;
use std::rc::Rc;

use ol4el::config::RunConfig;
use ol4el::coordinator::observer::from_fn;
use ol4el::coordinator::RunEvent;
use ol4el::net::{ChurnSpec, FleetReport, FleetSim, NetworkSpec, Topology};
use ol4el::strategy::StrategySpec;

/// Run a fleet at `shards`, capturing the complete event stream.
fn run_captured(cfg: RunConfig, shards: usize) -> (Vec<RunEvent>, FleetReport) {
    let events = Rc::new(RefCell::new(Vec::new()));
    let sink = events.clone();
    let report = FleetSim::new(cfg)
        .unwrap()
        .shards(shards)
        .observe(from_fn(move |ev: &RunEvent| {
            sink.borrow_mut().push(ev.clone());
        }))
        .run()
        .unwrap();
    let events = Rc::try_unwrap(events).unwrap().into_inner();
    (events, report)
}

/// Protocol fields that must not depend on the shard count
/// (`peak_queue_depth` and host timings legitimately do).
fn assert_reports_equal(a: &FleetReport, b: &FleetReport, what: &str) {
    assert_eq!(a.updates, b.updates, "{what}: updates");
    assert_eq!(a.wall_ms, b.wall_ms, "{what}: wall_ms");
    assert_eq!(a.mean_spent, b.mean_spent, "{what}: mean_spent");
    assert_eq!(a.final_progress, b.final_progress, "{what}: final_progress");
    assert_eq!(a.retired, b.retired, "{what}: retired");
    assert_eq!(a.joined, b.joined, "{what}: joined");
    assert_eq!(a.messages_sent, b.messages_sent, "{what}: messages_sent");
    assert_eq!(a.messages_lost, b.messages_lost, "{what}: messages_lost");
    assert_eq!(
        a.dropped_attempts, b.dropped_attempts,
        "{what}: dropped_attempts"
    );
    assert_eq!(a.events, b.events, "{what}: events");
}

fn equivalence_cfg(strategy: StrategySpec, seed: u64) -> RunConfig {
    RunConfig {
        strategy,
        n_edges: 60,
        hetero: 4.0,
        budget: 900.0,
        data_n: 3000, // ignored by the fleet; satisfies validate()
        eval_every: 20,
        // Lognormal latency has zero lookahead — the adversarial case for
        // conservative windows (every window degenerates to one instant).
        network: NetworkSpec::parse("lognormal:5:0.5,drop:0.02").unwrap(),
        churn: ChurnSpec::parse("poisson:0.2,join:1,restart:400,straggle:0.1:3").unwrap(),
        seed,
        ..Default::default()
    }
}

#[test]
fn async_event_stream_identical_across_shard_counts() {
    let cfg = equivalence_cfg(StrategySpec::ol4el_async(), 11);
    let (ref_events, ref_report) = run_captured(cfg.clone(), 1);
    assert!(ref_report.updates > 0, "reference run made no updates");
    assert!(
        ref_events.iter().any(|e| matches!(e, RunEvent::Finished { .. })),
        "stream must close with Finished"
    );
    for shards in [2, 4, 7] {
        let (events, report) = run_captured(cfg.clone(), shards);
        assert_eq!(
            events.len(),
            ref_events.len(),
            "async {shards}-shard stream length"
        );
        assert_eq!(events, ref_events, "async {shards}-shard stream diverged");
        assert_reports_equal(&ref_report, &report, &format!("async {shards} shards"));
    }
}

#[test]
fn sync_event_stream_identical_across_shard_counts() {
    let cfg = equivalence_cfg(StrategySpec::ol4el_sync(), 23);
    let (ref_events, ref_report) = run_captured(cfg.clone(), 1);
    assert!(ref_report.updates > 0, "reference run made no updates");
    for shards in [2, 4, 7] {
        let (events, report) = run_captured(cfg.clone(), shards);
        assert_eq!(events, ref_events, "sync {shards}-shard stream diverged");
        assert_reports_equal(&ref_report, &report, &format!("sync {shards} shards"));
    }
}

#[test]
fn equivalence_holds_across_seeds_and_modes() {
    // A broader (but shallower) sweep: sync and async, three seeds,
    // 1 vs 4 shards, protocol reports bit-equal.
    for strategy in [StrategySpec::ol4el_async(), StrategySpec::ol4el_sync()] {
        for seed in [1, 7, 42] {
            let cfg = equivalence_cfg(strategy.clone(), seed);
            let (_, one) = run_captured(cfg.clone(), 1);
            let (_, four) = run_captured(cfg, 4);
            assert_reports_equal(&one, &four, &format!("{strategy} seed {seed}"));
        }
    }
}

#[test]
fn window_barrier_boundary_latency_equal_to_lookahead() {
    // With `fixed:8` latency and unlimited bandwidth the lookahead is
    // exactly 8 ms, so every delivered message sent at a window's opening
    // instant arrives EXACTLY at the window bound — the boundary the
    // conservative synchronization must classify as "next window". Any
    // off-by-one in the window arithmetic (processing `<= bound` instead
    // of `< bound`, or dropping an arrival at the bound) breaks the
    // equivalence or loses messages.
    for strategy in [StrategySpec::ol4el_async(), StrategySpec::ol4el_sync()] {
        let cfg = RunConfig {
            strategy: strategy.clone(),
            n_edges: 40,
            hetero: 3.0,
            budget: 800.0,
            data_n: 3000,
            eval_every: 10,
            network: NetworkSpec::parse("fixed:8").unwrap(),
            churn: ChurnSpec::parse("poisson:0.1,restart:300").unwrap(),
            seed: 5,
            ..Default::default()
        };
        let (ref_events, ref_report) = run_captured(cfg.clone(), 1);
        assert!(ref_report.updates > 0, "{strategy}: no updates at the boundary");
        for shards in [2, 4] {
            let (events, report) = run_captured(cfg.clone(), shards);
            assert_eq!(
                events, ref_events,
                "{strategy} {shards}-shard boundary stream diverged"
            );
            assert_reports_equal(&ref_report, &report, &format!("{strategy} boundary"));
        }
    }
}

#[test]
fn zero_latency_ideal_network_still_exact() {
    // The fully degenerate case: ideal network, zero lookahead AND
    // zero-delay messages — every window collapses to cascades at a
    // single instant. No parallelism, but the contract must hold.
    let cfg = RunConfig {
        n_edges: 50,
        hetero: 5.0,
        budget: 700.0,
        data_n: 3000,
        eval_every: 25,
        seed: 3,
        ..Default::default()
    };
    let (ref_events, ref_report) = run_captured(cfg.clone(), 1);
    let (events, report) = run_captured(cfg, 4);
    assert_eq!(events, ref_events, "ideal-network stream diverged");
    assert_reports_equal(&ref_report, &report, "ideal network");
}

#[test]
fn tree_one_event_stream_identical_to_flat() {
    // A single-region tree IS the flat protocol (the runner routes
    // tree:1 through the flat drivers), so the FULL event stream — every
    // payload f64 — must be bit-identical, for both manners, at any
    // shard count.
    for (strategy, seed) in [
        (StrategySpec::ol4el_async(), 11),
        (StrategySpec::ol4el_sync(), 23),
    ] {
        let flat_cfg = equivalence_cfg(strategy.clone(), seed);
        let mut tree_cfg = flat_cfg.clone();
        tree_cfg.topology = Topology::parse("tree:1").unwrap();
        for shards in [1, 4] {
            let (flat_events, flat_report) = run_captured(flat_cfg.clone(), shards);
            let (tree_events, tree_report) = run_captured(tree_cfg.clone(), shards);
            assert!(flat_report.updates > 0, "{strategy}: no updates");
            assert_eq!(
                tree_events, flat_events,
                "{strategy} tree:1 stream diverged from flat at {shards} shards"
            );
            assert_reports_equal(
                &flat_report,
                &tree_report,
                &format!("{strategy} tree:1 vs flat, {shards} shards"),
            );
        }
    }
}

#[test]
fn hier_tree_event_stream_identical_across_shard_counts() {
    // The determinism contract extends to real trees: a tree:4 run under
    // the adversarial zero-lookahead config (lognormal latency + Poisson
    // churn with restarts and stragglers) must produce the identical
    // RunEvent stream at shards ∈ {1, 2, 4}.
    for (strategy, seed) in [
        (StrategySpec::ol4el_async(), 31),
        (StrategySpec::ol4el_sync(), 47),
    ] {
        let mut cfg = equivalence_cfg(strategy.clone(), seed);
        cfg.topology = Topology::parse("tree:4").unwrap();
        let (ref_events, ref_report) = run_captured(cfg.clone(), 1);
        assert!(ref_report.updates > 0, "{strategy}: hier run made no updates");
        assert!(
            ref_events.iter().any(|e| matches!(e, RunEvent::Finished { .. })),
            "hier stream must close with Finished"
        );
        for shards in [2, 4] {
            let (events, report) = run_captured(cfg.clone(), shards);
            assert_eq!(
                events, ref_events,
                "{strategy} tree:4 {shards}-shard stream diverged"
            );
            assert_reports_equal(
                &ref_report,
                &report,
                &format!("{strategy} tree:4, {shards} shards"),
            );
        }
    }
}
