//! Loopback end-to-end tests for the real networked deployment
//! (`net::wire`): `ol4el coordinator serve` + N `ol4el edge join`
//! processes on 127.0.0.1, asserted bit-identical to the in-process
//! `ol4el train` run with the same config — including through a
//! crash-and-rejoin — and terminating when an edge dies for good.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ol4el::testkit::poll_until;
use ol4el::util::json::Json;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_ol4el")
}

/// A port the OS just handed out (freed before use; the window between
/// drop and the coordinator's bind is the standard acceptable race).
fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .expect("bind :0")
        .local_addr()
        .expect("local addr")
        .port()
}

/// Child processes killed on drop, so a failing assertion can't leak
/// edge processes that retry-connect for the rest of the test run.
struct Procs(Vec<Child>);

impl Drop for Procs {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Wait for `child` with a hard timeout, returning its output (stdout
/// must be piped). Kills and panics on timeout.
fn wait_output(mut child: Child, secs: u64, what: &str) -> std::process::Output {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        match child.try_wait().expect("try_wait") {
            Some(_) => return child.wait_with_output().expect("wait_with_output"),
            None if Instant::now() > deadline => {
                let _ = child.kill();
                let _ = child.wait();
                panic!("{what} timed out after {secs}s");
            }
            None => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

/// Poll the live stats endpoint until the coordinator has served at
/// least `rounds` local rounds — the run is demonstrably underway. The
/// shared `testkit::poll_until` replaces the fixed sleeps this file used
/// to carry: readiness is detected as soon as it is true, and a slow CI
/// machine gets the whole deadline.
fn wait_for_rounds(addr: &str, rounds: f64, secs: u64) {
    let ok = poll_until(
        Duration::from_secs(secs),
        Duration::from_millis(50),
        || {
            let Ok(out) = Command::new(bin())
                .args(["coordinator", "stats", "--addr", addr, "--timeout-ms", "500"])
                .output()
            else {
                return false;
            };
            if !out.status.success() {
                return false;
            }
            let Ok(text) = String::from_utf8(out.stdout) else {
                return false;
            };
            let Ok(j) = Json::parse(&text) else {
                return false;
            };
            j.get("counters")
                .and_then(|c| c.get("wire.server.rounds"))
                .and_then(Json::as_f64)
                .is_some_and(|n| n >= rounds)
        },
    );
    assert!(ok, "coordinator at {addr} never reached {rounds} served rounds");
}

/// The shared run configuration: small enough to finish in seconds,
/// big enough to make many strategy decisions and global updates.
fn config_args(strategy: &str, budget: &str) -> Vec<String> {
    [
        "--task",
        "svm",
        "--strategy",
        strategy,
        "--edges",
        "3",
        "--budget",
        budget,
        "--data-n",
        "4000",
        "--seed",
        "7",
        "--eval-every",
        "1",
        "--json",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Run in-process `ol4el train` and return its parsed `--json` output.
fn local_run(strategy: &str, budget: &str) -> Json {
    let out = Command::new(bin())
        .arg("train")
        .args(config_args(strategy, budget))
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn train");
    let out = wait_output(out, 120, "ol4el train");
    assert!(out.status.success(), "train exited nonzero");
    Json::parse(&String::from_utf8(out.stdout).expect("utf8")).expect("train json")
}

/// Run `coordinator serve` + one `edge join` process per entry of
/// `edge_flags` and return serve's parsed `--json` output.
fn distributed_run(
    strategy: &str,
    budget: &str,
    serve_extra: &[&str],
    edge_flags: &[&[&str]],
) -> Json {
    let addr = format!("127.0.0.1:{}", free_port());
    let serve = Command::new(bin())
        .args(["coordinator", "serve", "--addr", &addr])
        .args(config_args(strategy, budget))
        .args(serve_extra)
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut edges = Procs(Vec::new());
    for flags in edge_flags {
        edges.0.push(
            Command::new(bin())
                .args(["edge", "join", &addr])
                .args(*flags)
                .stdout(Stdio::null())
                .spawn()
                .expect("spawn edge"),
        );
    }
    let out = wait_output(serve, 180, "coordinator serve");
    assert!(
        out.status.success(),
        "serve exited nonzero: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // Shutdown frames end every edge process cleanly.
    for e in std::mem::take(&mut edges.0) {
        let out = wait_output(e, 60, "edge join");
        assert!(out.status.success(), "an edge exited nonzero");
    }
    Json::parse(&String::from_utf8(out.stdout).expect("utf8")).expect("serve json")
}

/// Assert two run documents are bit-identical in everything that is not
/// host wall-clock: the full TracePoint stream and the summary scalars.
fn assert_bit_identical(local: &Json, dist: &Json, what: &str) {
    for key in [
        "final_metric",
        "updates",
        "wall_ms",
        "mean_spent",
        "retired_edges",
        "trace",
        "config",
    ] {
        assert_eq!(
            local.get(key),
            dist.get(key),
            "{what}: '{key}' differs between in-process train and the wire"
        );
    }
    let n = dist
        .get("trace")
        .and_then(Json::as_arr)
        .map(<[Json]>::len)
        .unwrap_or(0);
    assert!(n > 3, "{what}: only {n} trace points — run too trivial to prove anything");
}

#[test]
fn sync_session_is_bit_identical_over_the_wire() {
    let strategy = "ol4el:mode=sync";
    let local = local_run(strategy, "1500");
    let dist = distributed_run(strategy, "1500", &[], &[&[], &[], &[]]);
    assert_bit_identical(&local, &dist, "sync");
}

#[test]
fn async_session_with_a_mid_round_crash_is_bit_identical() {
    // One edge drops its connection after computing round 3 *without
    // reporting it*, then rejoins: the coordinator resends the launch,
    // the edge fast-forwards and recomputes the identical round, and the
    // final document still matches the crash-free in-process run bit for
    // bit — the ISSUE's deterministic-crash-recovery acceptance test.
    let strategy = "ol4el";
    let local = local_run(strategy, "1500");
    let dist = distributed_run(
        strategy,
        "1500",
        &[],
        &[
            &["--drop-round", "3", "--max-backoff-ms", "250"],
            &[],
            &[],
        ],
    );
    assert_bit_identical(&local, &dist, "async+crash");
}

#[test]
fn clean_leave_retires_the_edge_and_the_session_finishes() {
    let dist = distributed_run(
        "ol4el",
        "1500",
        &[],
        &[&["--leave-after", "2"], &[], &[]],
    );
    let retired = dist
        .get("retired_edges")
        .and_then(Json::as_f64)
        .expect("retired_edges");
    assert!(
        retired >= 1.0,
        "a clean Leave must retire the departing edge (got {retired})"
    );
}

#[test]
fn session_survives_a_permanently_dead_edge() {
    // SIGKILL one edge process mid-run and never bring it back: the
    // coordinator waits out the (short) rejoin window, retires the edge,
    // and the session still terminates with a clean exit. A large budget
    // keeps the session alive well past the kill; if the race is ever
    // lost the test degrades to a plain three-edge run, not a failure.
    let addr = format!("127.0.0.1:{}", free_port());
    let serve = Command::new(bin())
        .args(["coordinator", "serve", "--addr", &addr])
        .args(config_args("ol4el", "60000"))
        .args(["--rejoin-window-ms", "500", "--round-timeout-ms", "10000"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut edges = Procs(Vec::new());
    for _ in 0..3 {
        edges.0.push(
            Command::new(bin())
                .args(["edge", "join", &addr])
                .stdout(Stdio::null())
                .spawn()
                .expect("spawn edge"),
        );
    }
    wait_for_rounds(&addr, 3.0, 60);
    let victim = &mut edges.0[2];
    let _ = victim.kill();
    let _ = victim.wait();
    let out = wait_output(serve, 180, "coordinator serve (dead edge)");
    assert!(
        out.status.success(),
        "serve must terminate cleanly with a permanently dead edge: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let j = Json::parse(&String::from_utf8(out.stdout).expect("utf8")).expect("serve json");
    assert!(j.get("updates").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
}

#[test]
fn killed_coordinator_restarts_with_resume_and_matches_the_baseline() {
    // The elastic-service acceptance test: SIGKILL `coordinator serve`
    // mid-run, restart it with `--resume` from its own periodic
    // checkpoint, and the surviving `edge join` processes reconnect
    // through their ordinary backoff loop. The restarted session's --json
    // report must equal the never-killed in-process baseline bit for bit.
    let strategy = "ol4el";
    let budget = "4000";
    let local = local_run(strategy, budget);

    let dir = std::env::temp_dir().join(format!("ol4el-wire-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let ckpt = dir.join("serve.json");
    let ckpt_s = ckpt.to_str().expect("utf8 path").to_string();
    let addr = format!("127.0.0.1:{}", free_port());
    let ckpt_flags = ["--checkpoint-every", "2", "--checkpoint-to", &ckpt_s];
    let serve1 = Command::new(bin())
        .args(["coordinator", "serve", "--addr", &addr])
        .args(config_args(strategy, budget))
        .args(ckpt_flags)
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut serve1 = Procs(vec![serve1]);
    let mut edges = Procs(Vec::new());
    for _ in 0..3 {
        edges.0.push(
            Command::new(bin())
                .args(["edge", "join", &addr, "--max-backoff-ms", "250"])
                .stdout(Stdio::null())
                .spawn()
                .expect("spawn edge"),
        );
    }
    // Kill as soon as a mid-run snapshot lands on disk (cadence 2 with a
    // generous budget: the run is nowhere near done at that point).
    let wrote = poll_until(
        Duration::from_secs(60),
        Duration::from_millis(25),
        || ckpt.exists(),
    );
    assert!(wrote, "the coordinator never wrote {}", ckpt.display());
    {
        let victim = &mut serve1.0[0];
        let _ = victim.kill(); // SIGKILL: no shutdown frames, no flush
        let _ = victim.wait();
    }
    let serve2 = Command::new(bin())
        .args(["coordinator", "serve", "--addr", &addr])
        .args(config_args(strategy, budget))
        .args(ckpt_flags)
        .args(["--resume", &ckpt_s])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn resumed serve");
    let out = wait_output(serve2, 180, "coordinator serve --resume");
    assert!(
        out.status.success(),
        "resumed serve exited nonzero: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // Every surviving edge reconnected, was re-welcomed at its banked
    // iteration count, and exits cleanly on the resumed session's
    // Shutdown.
    for e in std::mem::take(&mut edges.0) {
        let out = wait_output(e, 60, "edge join (across the restart)");
        assert!(out.status.success(), "an edge did not survive the coordinator restart");
    }
    let resumed = Json::parse(&String::from_utf8(out.stdout).expect("utf8")).expect("serve json");
    assert_bit_identical(&local, &resumed, "kill+resume");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_endpoint_serves_the_latest_snapshot() {
    // The CheckpointReq wire endpoint: while a checkpointing session is
    // live, any client can fetch the latest snapshot document pre-Hello
    // (the same path a monitoring sidecar or a warm standby would use).
    let dir = std::env::temp_dir().join(format!("ol4el-wire-fetch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let ckpt = dir.join("serve.json");
    let ckpt_s = ckpt.to_str().expect("utf8 path").to_string();
    let addr = format!("127.0.0.1:{}", free_port());
    let serve = Command::new(bin())
        .args(["coordinator", "serve", "--addr", &addr])
        .args(config_args("ol4el", "4000"))
        .args(["--checkpoint-every", "2", "--checkpoint-to", &ckpt_s])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut edges = Procs(Vec::new());
    for _ in 0..3 {
        edges.0.push(
            Command::new(bin())
                .args(["edge", "join", &addr])
                .stdout(Stdio::null())
                .spawn()
                .expect("spawn edge"),
        );
    }
    let wrote = poll_until(
        Duration::from_secs(60),
        Duration::from_millis(25),
        || ckpt.exists(),
    );
    assert!(wrote, "the coordinator never wrote {}", ckpt.display());
    let doc = ol4el::net::wire::fetch_checkpoint(&addr, Duration::from_secs(10))
        .expect("fetch_checkpoint");
    assert!(
        doc.get("version").is_some() && doc.get("config").is_some(),
        "fetched checkpoint is not a snapshot document: {doc}"
    );
    let out = wait_output(serve, 180, "coordinator serve (checkpoint endpoint)");
    assert!(out.status.success());
    for e in std::mem::take(&mut edges.0) {
        let out = wait_output(e, 60, "edge join");
        assert!(out.status.success(), "an edge exited nonzero");
    }
    std::fs::remove_dir_all(&dir).ok();
}
