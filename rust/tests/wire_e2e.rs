//! Loopback end-to-end tests for the real networked deployment
//! (`net::wire`): `ol4el coordinator serve` + N `ol4el edge join`
//! processes on 127.0.0.1, asserted bit-identical to the in-process
//! `ol4el train` run with the same config — including through a
//! crash-and-rejoin — and terminating when an edge dies for good.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ol4el::util::json::Json;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_ol4el")
}

/// A port the OS just handed out (freed before use; the window between
/// drop and the coordinator's bind is the standard acceptable race).
fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .expect("bind :0")
        .local_addr()
        .expect("local addr")
        .port()
}

/// Child processes killed on drop, so a failing assertion can't leak
/// edge processes that retry-connect for the rest of the test run.
struct Procs(Vec<Child>);

impl Drop for Procs {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Wait for `child` with a hard timeout, returning its output (stdout
/// must be piped). Kills and panics on timeout.
fn wait_output(mut child: Child, secs: u64, what: &str) -> std::process::Output {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        match child.try_wait().expect("try_wait") {
            Some(_) => return child.wait_with_output().expect("wait_with_output"),
            None if Instant::now() > deadline => {
                let _ = child.kill();
                let _ = child.wait();
                panic!("{what} timed out after {secs}s");
            }
            None => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

/// The shared run configuration: small enough to finish in seconds,
/// big enough to make many strategy decisions and global updates.
fn config_args(strategy: &str, budget: &str) -> Vec<String> {
    [
        "--task",
        "svm",
        "--strategy",
        strategy,
        "--edges",
        "3",
        "--budget",
        budget,
        "--data-n",
        "4000",
        "--seed",
        "7",
        "--eval-every",
        "1",
        "--json",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Run in-process `ol4el train` and return its parsed `--json` output.
fn local_run(strategy: &str, budget: &str) -> Json {
    let out = Command::new(bin())
        .arg("train")
        .args(config_args(strategy, budget))
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn train");
    let out = wait_output(out, 120, "ol4el train");
    assert!(out.status.success(), "train exited nonzero");
    Json::parse(&String::from_utf8(out.stdout).expect("utf8")).expect("train json")
}

/// Run `coordinator serve` + one `edge join` process per entry of
/// `edge_flags` and return serve's parsed `--json` output.
fn distributed_run(
    strategy: &str,
    budget: &str,
    serve_extra: &[&str],
    edge_flags: &[&[&str]],
) -> Json {
    let addr = format!("127.0.0.1:{}", free_port());
    let serve = Command::new(bin())
        .args(["coordinator", "serve", "--addr", &addr])
        .args(config_args(strategy, budget))
        .args(serve_extra)
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut edges = Procs(Vec::new());
    for flags in edge_flags {
        edges.0.push(
            Command::new(bin())
                .args(["edge", "join", &addr])
                .args(*flags)
                .stdout(Stdio::null())
                .spawn()
                .expect("spawn edge"),
        );
    }
    let out = wait_output(serve, 180, "coordinator serve");
    assert!(
        out.status.success(),
        "serve exited nonzero: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // Shutdown frames end every edge process cleanly.
    for e in std::mem::take(&mut edges.0) {
        let out = wait_output(e, 60, "edge join");
        assert!(out.status.success(), "an edge exited nonzero");
    }
    Json::parse(&String::from_utf8(out.stdout).expect("utf8")).expect("serve json")
}

/// Assert two run documents are bit-identical in everything that is not
/// host wall-clock: the full TracePoint stream and the summary scalars.
fn assert_bit_identical(local: &Json, dist: &Json, what: &str) {
    for key in [
        "final_metric",
        "updates",
        "wall_ms",
        "mean_spent",
        "retired_edges",
        "trace",
        "config",
    ] {
        assert_eq!(
            local.get(key),
            dist.get(key),
            "{what}: '{key}' differs between in-process train and the wire"
        );
    }
    let n = dist
        .get("trace")
        .and_then(Json::as_arr)
        .map(<[Json]>::len)
        .unwrap_or(0);
    assert!(n > 3, "{what}: only {n} trace points — run too trivial to prove anything");
}

#[test]
fn sync_session_is_bit_identical_over_the_wire() {
    let strategy = "ol4el:mode=sync";
    let local = local_run(strategy, "1500");
    let dist = distributed_run(strategy, "1500", &[], &[&[], &[], &[]]);
    assert_bit_identical(&local, &dist, "sync");
}

#[test]
fn async_session_with_a_mid_round_crash_is_bit_identical() {
    // One edge drops its connection after computing round 3 *without
    // reporting it*, then rejoins: the coordinator resends the launch,
    // the edge fast-forwards and recomputes the identical round, and the
    // final document still matches the crash-free in-process run bit for
    // bit — the ISSUE's deterministic-crash-recovery acceptance test.
    let strategy = "ol4el";
    let local = local_run(strategy, "1500");
    let dist = distributed_run(
        strategy,
        "1500",
        &[],
        &[
            &["--drop-round", "3", "--max-backoff-ms", "250"],
            &[],
            &[],
        ],
    );
    assert_bit_identical(&local, &dist, "async+crash");
}

#[test]
fn clean_leave_retires_the_edge_and_the_session_finishes() {
    let dist = distributed_run(
        "ol4el",
        "1500",
        &[],
        &[&["--leave-after", "2"], &[], &[]],
    );
    let retired = dist
        .get("retired_edges")
        .and_then(Json::as_f64)
        .expect("retired_edges");
    assert!(
        retired >= 1.0,
        "a clean Leave must retire the departing edge (got {retired})"
    );
}

#[test]
fn session_survives_a_permanently_dead_edge() {
    // SIGKILL one edge process mid-run and never bring it back: the
    // coordinator waits out the (short) rejoin window, retires the edge,
    // and the session still terminates with a clean exit. A large budget
    // keeps the session alive well past the kill; if the race is ever
    // lost the test degrades to a plain three-edge run, not a failure.
    let addr = format!("127.0.0.1:{}", free_port());
    let serve = Command::new(bin())
        .args(["coordinator", "serve", "--addr", &addr])
        .args(config_args("ol4el", "60000"))
        .args(["--rejoin-window-ms", "500", "--round-timeout-ms", "10000"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let mut edges = Procs(Vec::new());
    for _ in 0..3 {
        edges.0.push(
            Command::new(bin())
                .args(["edge", "join", &addr])
                .stdout(Stdio::null())
                .spawn()
                .expect("spawn edge"),
        );
    }
    std::thread::sleep(Duration::from_millis(750));
    let victim = &mut edges.0[2];
    let _ = victim.kill();
    let _ = victim.wait();
    let out = wait_output(serve, 180, "coordinator serve (dead edge)");
    assert!(
        out.status.success(),
        "serve must terminate cleanly with a permanently dead edge: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let j = Json::parse(&String::from_utf8(out.stdout).expect("utf8")).expect("serve json");
    assert!(j.get("updates").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
}
