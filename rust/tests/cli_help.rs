//! Doc/help drift guard: the spec-grammar reference is single-sourced
//! from `docs/GRAMMAR.md` (via `util::cli::SPEC_GRAMMAR`), included
//! verbatim in `ol4el --help`, and linked from the README. This test runs
//! the real binary and asserts the help output contains every grammar
//! production, so the CLI and the written docs cannot drift apart.

use std::process::Command;

/// Every production of every spec grammar, as spelled in docs/GRAMMAR.md.
const PRODUCTIONS: &[&str] = &[
    // task
    "task     := NAME ( ':' KEY '=' N )*",
    "'svm'",
    "'kmeans'",
    "'logreg'",
    "'gmm'",
    "k=CLUSTERS",
    "d=DIM c=CLASSES",
    "k=COMPONENTS",
    // strategy
    "strategy := NAME ( ':' KEY '=' V )*",
    "'ol4el'   bandit=B eps=E",
    "'fixed-i' i=N",
    "'ac-sync'",
    "'greedy-budget' deadline=MS",
    "mode=sync|async",
    "'ol4el-sync' | 'ol4el-async'",
    "sugar for ol4el:bandit=B",
    // network
    "ideal",
    "fixed:MS",
    "uniform:LO:HI",
    "lognormal:MEDIAN_MS:SIGMA",
    "bw:MBPS",
    "drop:P",
    "timeout:MS",
    "retries:N",
    "part:START-END",
    // churn
    "none",
    "poisson:LEAVE",
    "join:RATE",
    "restart:MS",
    "straggle:P:FACTOR",
    // topology (hierarchical aggregation)
    "topology := 'flat' | 'tree:R' [ ':fanout=N' ]",
    "region = id mod R",
    // real deployment (net::wire)
    "addr     := HOST ':' PORT",
    "'coordinator serve' '--addr' addr",
    "'edge join' addr",
    "['--slowdown' S]",
    "['--leave-after' N]",
    "['--rejoin' ID]",
    "['--drop-round' N]",
    // telemetry (the observability surface)
    "telemetry := '--telemetry' FILE",
    "['--telemetry-sample' N]",
    "'coordinator stats' '--addr' addr",
    "['--format' 'json'|'prom']",
    // checkpoint/resume (the elastic service surface)
    "checkpoint := '--checkpoint-every' N",
    "['--checkpoint-to' FILE]",
    "resume   := '--resume' FILE",
    // data-parallel engine knobs (the batched stepping surface)
    "threads  := '--threads' ( N | 'max' )",
    "edge-batch := '--edge-batch' N",
    // bandit (the legacy form; also the bandit= values of ol4el)
    "auto",
    "kube[:EPS]",
    "ucb-bv",
    "ucb1",
    "eps-greedy[:EPS]",
    "thompson",
    // partition
    "iid",
    "label-skew[:ALPHA]",
    // scalar enums
    "'fixed' | 'variable[:CV]' | 'measured'",
    "'linear' | 'random'",
    "'eval' | 'delta'",
];

fn help_output() -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_ol4el"))
        .arg("--help")
        .output()
        .expect("run ol4el --help");
    assert!(out.status.success(), "--help exited nonzero");
    String::from_utf8(out.stdout).expect("utf8 help output")
}

fn subcommand_help(sub: &str) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_ol4el"))
        .args([sub, "--help"])
        .output()
        .unwrap_or_else(|e| panic!("run ol4el {sub} --help: {e}"));
    assert!(out.status.success(), "{sub} --help exited nonzero");
    String::from_utf8(out.stdout).expect("utf8 help output")
}

#[test]
fn help_contains_every_grammar_production() {
    let help = help_output();
    for prod in PRODUCTIONS {
        assert!(
            help.contains(prod),
            "`ol4el --help` lost grammar production {prod:?} — \
             docs/GRAMMAR.md and the CLI have drifted"
        );
    }
}

#[test]
fn help_is_the_single_sourced_grammar() {
    // The help must embed SPEC_GRAMMAR verbatim (not a paraphrase).
    let help = help_output();
    assert!(
        help.contains(ol4el::util::cli::SPEC_GRAMMAR),
        "--help no longer includes docs/GRAMMAR.md verbatim"
    );
}

#[test]
fn spec_grammar_parses_its_own_examples() {
    // The examples documented in the grammar must actually parse.
    use ol4el::bandit::BanditSpec;
    use ol4el::config::PartitionKind;
    use ol4el::model::TaskSpec;
    use ol4el::net::{ChurnSpec, NetworkSpec, Topology};
    use ol4el::sim::cost::CostMode;
    use ol4el::strategy::StrategySpec;
    assert!(TaskSpec::parse("kmeans:k=5").is_ok());
    assert!(TaskSpec::parse("logreg:d=59:c=8").is_ok());
    assert!(TaskSpec::parse("gmm:k=3").is_ok());
    assert!(StrategySpec::parse("ol4el:bandit=kube:eps=0.1").is_ok());
    assert!(StrategySpec::parse("fixed-i:i=8").is_ok());
    assert!(StrategySpec::parse("ac-sync").is_ok());
    assert!(StrategySpec::parse("greedy-budget:deadline=500").is_ok());
    assert!(StrategySpec::parse("thompson").is_ok());
    assert!(NetworkSpec::parse("lognormal:5:0.5,bw:10,drop:0.01").is_some());
    assert!(NetworkSpec::parse("fixed:20,part:1000-2500").is_some());
    assert!(ChurnSpec::parse("poisson:0.01,join:0.05").is_some());
    assert!(ChurnSpec::parse("poisson:0.2,restart:500,straggle:0.1:4").is_some());
    assert!(Topology::parse("flat").is_some());
    assert!(Topology::parse("tree:32").is_some());
    assert!(Topology::parse("tree:8:fanout=4").is_some());
    // Degenerate trees parse syntactically but fail validation.
    assert!(Topology::parse("tree:0").unwrap().check(10).is_err());
    assert!(Topology::parse("tree:4:fanout=0").unwrap().check(10).is_err());
    assert!(BanditSpec::parse("kube:0.2").is_some());
    assert!(PartitionKind::parse("label-skew:0.3").is_some());
    assert!(CostMode::parse("variable:0.35").is_some());
}

/// `ol4el SUB SUBSUB --help` (two-level subcommands: `coordinator serve`,
/// `edge join`).
fn nested_help(sub: &str, subsub: &str) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_ol4el"))
        .args([sub, subsub, "--help"])
        .output()
        .unwrap_or_else(|e| panic!("run ol4el {sub} {subsub} --help: {e}"));
    assert!(out.status.success(), "{sub} {subsub} --help exited nonzero");
    String::from_utf8(out.stdout).expect("utf8 help output")
}

#[test]
fn coordinator_and_edge_help_document_the_wire_grammar() {
    // The deployment grammar is single-sourced in `util::cli::WIRE_GRAMMAR`
    // and must show up in both process-split entry points.
    for sub in ["coordinator", "edge"] {
        let help = subcommand_help(sub);
        assert!(
            help.contains(ol4el::util::cli::WIRE_GRAMMAR),
            "{sub} --help lost the single-sourced wire grammar"
        );
    }
}

#[test]
fn serve_and_join_help_document_their_flags() {
    let serve = nested_help("coordinator", "serve");
    for needle in ["--addr", "--round-timeout-ms", "--rejoin-window-ms", "--task", "--strategy"] {
        assert!(serve.contains(needle), "coordinator serve --help lost {needle:?}");
    }
    let join = nested_help("edge", "join");
    for needle in ["--slowdown", "--leave-after", "--rejoin", "--drop-round", "--max-backoff-ms"] {
        assert!(join.contains(needle), "edge join --help lost {needle:?}");
    }
}

#[test]
fn telemetry_flags_document_everywhere_they_exist() {
    // Satellite: the telemetry surface is uniform — every long-running
    // entry point (train, fleet, coordinator serve, edge join) takes
    // --telemetry FILE and --telemetry-sample N, and the coordinator
    // exposes a `stats` scrape subcommand.
    for help in [
        subcommand_help("train"),
        subcommand_help("fleet"),
        nested_help("coordinator", "serve"),
        nested_help("edge", "join"),
    ] {
        for needle in ["--telemetry", "--telemetry-sample"] {
            assert!(help.contains(needle), "a telemetry entry point lost {needle:?}");
        }
    }
    let stats = nested_help("coordinator", "stats");
    for needle in ["--addr", "--format", "--timeout-ms"] {
        assert!(stats.contains(needle), "coordinator stats --help lost {needle:?}");
    }
}

#[test]
fn checkpoint_flags_document_everywhere_they_exist() {
    // Satellite: the checkpoint/resume surface is uniform — both session
    // owners (train and coordinator serve) take --checkpoint-every,
    // --checkpoint-to and --resume, and the coordinator help teaches the
    // single-sourced grammar one-liner.
    for help in [subcommand_help("train"), nested_help("coordinator", "serve")] {
        for needle in ["--checkpoint-every", "--checkpoint-to", "--resume"] {
            assert!(
                help.contains(needle),
                "a checkpointing entry point lost {needle:?}"
            );
        }
    }
    assert!(
        subcommand_help("coordinator").contains(ol4el::util::cli::CHECKPOINT_GRAMMAR),
        "coordinator --help lost the single-sourced checkpoint grammar"
    );
}

#[test]
fn bench_flags_document_everywhere_they_exist() {
    // Satellite: the data-parallelism knobs are uniform — deploy and
    // bench-tasks take both --threads and --edge-batch; bench-strategies
    // takes --threads (its decision loop has no engine compute, the flag
    // is recorded as run metadata).
    for sub in ["deploy", "bench-tasks"] {
        let help = subcommand_help(sub);
        for needle in ["--threads", "--edge-batch"] {
            assert!(help.contains(needle), "{sub} --help lost {needle:?}");
        }
    }
    assert!(
        subcommand_help("bench-strategies").contains("--threads"),
        "bench-strategies --help lost --threads"
    );
}

#[test]
fn train_help_documents_the_task_spec_grammar() {
    // The train subcommand's --task flag must teach the registry grammar.
    let help = subcommand_help("train");
    for needle in ["--task", "logreg", "gmm", "kmeans:k=5"] {
        assert!(help.contains(needle), "train --help lost {needle:?}");
    }
}

#[test]
fn train_and_fleet_help_document_the_topology_grammar() {
    // Satellite: the aggregation-topology grammar is single-sourced in
    // `util::cli::TOPOLOGY_GRAMMAR` and must show up wherever a
    // --topology flag exists — train AND fleet.
    for sub in ["train", "fleet"] {
        let help = subcommand_help(sub);
        assert!(
            help.contains("--topology"),
            "{sub} --help lost the --topology flag"
        );
        assert!(
            help.contains(ol4el::util::cli::TOPOLOGY_GRAMMAR),
            "{sub} --help lost the single-sourced topology grammar"
        );
    }
}

#[test]
fn train_and_fleet_help_document_the_strategy_grammar() {
    // Satellite: the strategy grammar is single-sourced in
    // `util::cli::STRATEGY_GRAMMAR` (next to SPEC_GRAMMAR) and must show
    // up wherever a --strategy flag exists — train AND fleet.
    for sub in ["train", "fleet"] {
        let help = subcommand_help(sub);
        assert!(
            help.contains(ol4el::util::cli::STRATEGY_GRAMMAR),
            "{sub} --help lost the single-sourced strategy grammar"
        );
        for needle in [
            "--strategy",
            "ol4el[:bandit=B]",
            "fixed-i[:i=N]",
            "ac-sync",
            "greedy-budget[:deadline=MS]",
        ] {
            assert!(help.contains(needle), "{sub} --help lost {needle:?}");
        }
        // The legacy bandit alias teaches its grammar from the same
        // single-sourced string.
        assert!(
            help.contains(ol4el::util::cli::BANDIT_GRAMMAR),
            "{sub} --help lost the single-sourced bandit grammar"
        );
    }
}
