//! The determinism contract of the data-parallel surface, end to end:
//! the blocked multithreaded kernels must be bit-identical to the scalar
//! path at any thread count, and every learner's `local_step_batch` over
//! E edges must be bit-identical to E sequential `local_step` calls.
//! Perf may move; numbers may not.

use std::sync::Arc;

use ol4el::data::partition;
use ol4el::edge::Hyper;
use ol4el::engine::native::NativeEngine;
use ol4el::engine::{
    argmin_dist_groups_threads, argmin_dist_threads, gemm_bias_groups_threads,
    gemm_bias_threads, pool, scatter_add_groups_threads, CPU_OPS, EngineOps as _,
};
use ol4el::model::{registered_tasks, Learner as _, TaskSpec};
use ol4el::util::rng::Rng;

/// Thread counts exercised against the sequential reference: an even
/// split, and a prime that never divides the row counts evenly.
const THREADS: [usize; 2] = [2, 7];

/// Row counts straddling the parallel cutover: just below (sequential),
/// exactly at (first parallel size), and a count no block size divides.
fn row_cases() -> [usize; 3] {
    let cut = pool::PAR_CUTOVER_ROWS;
    [cut - 1, cut, cut + 101]
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

#[test]
fn threaded_gemm_bias_bit_identical_to_scalar() {
    let (d, c) = (17, 8);
    for n in row_cases() {
        let mut rng = Rng::new(42);
        let x = randn(&mut rng, n * d);
        let w = randn(&mut rng, d * c);
        let b = randn(&mut rng, c);
        let mut base = vec![0f32; n * c];
        gemm_bias_threads(1, &x, &w, &b, d, c, &mut base);
        for t in THREADS {
            let mut out = vec![0f32; n * c];
            gemm_bias_threads(t, &x, &w, &b, d, c, &mut out);
            assert_bits_eq(&base, &out, &format!("gemm_bias n={n} threads={t}"));
        }
    }
}

#[test]
fn threaded_argmin_dist_bit_identical_to_scalar() {
    let (d, k) = (11, 6);
    for n in row_cases() {
        let mut rng = Rng::new(7);
        let x = randn(&mut rng, n * d);
        let centers = randn(&mut rng, k * d);
        let mut base_assign = Vec::new();
        let base_inertia = argmin_dist_threads(1, &x, &centers, d, k, &mut base_assign);
        for t in THREADS {
            let mut assign = Vec::new();
            let inertia = argmin_dist_threads(t, &x, &centers, d, k, &mut assign);
            assert_eq!(base_assign, assign, "argmin assign n={n} threads={t}");
            assert_eq!(
                base_inertia.to_bits(),
                inertia.to_bits(),
                "argmin inertia n={n} threads={t}: {base_inertia} vs {inertia}"
            );
        }
    }
}

#[test]
fn grouped_kernels_bit_identical_to_per_group_loop() {
    let (d, c, k, groups, pn) = (9, 5, 4, 5, 70);
    let n = groups * pn; // 350 rows: past the cutover, so threads engage
    let mut rng = Rng::new(13);
    let x = randn(&mut rng, n * d);
    let w = randn(&mut rng, groups * d * c);
    let b = randn(&mut rng, groups * c);
    let centers = randn(&mut rng, groups * k * d);

    // Sequential per-group references.
    let mut gemm_ref = vec![0f32; n * c];
    for g in 0..groups {
        let mut out = vec![0f32; pn * c];
        gemm_bias_threads(
            1,
            &x[g * pn * d..(g + 1) * pn * d],
            &w[g * d * c..(g + 1) * d * c],
            &b[g * c..(g + 1) * c],
            d,
            c,
            &mut out,
        );
        gemm_ref[g * pn * c..(g + 1) * pn * c].copy_from_slice(&out);
    }
    let mut assign_ref: Vec<i32> = Vec::new();
    let mut inertia_ref = vec![0f32; groups];
    for g in 0..groups {
        let mut a = Vec::new();
        inertia_ref[g] = argmin_dist_threads(
            1,
            &x[g * pn * d..(g + 1) * pn * d],
            &centers[g * k * d..(g + 1) * k * d],
            d,
            k,
            &mut a,
        );
        assign_ref.extend_from_slice(&a);
    }
    let mut sums_ref = vec![0f32; groups * k * d];
    let mut counts_ref = vec![0f32; groups * k];
    for g in 0..groups {
        CPU_OPS.scatter_add(
            &x[g * pn * d..(g + 1) * pn * d],
            &assign_ref[g * pn..(g + 1) * pn],
            d,
            k,
            &mut sums_ref[g * k * d..(g + 1) * k * d],
            &mut counts_ref[g * k..(g + 1) * k],
        );
    }

    for t in [1, 2, 7] {
        let mut gemm_out = vec![0f32; n * c];
        gemm_bias_groups_threads(t, &x, &w, &b, d, c, groups, &mut gemm_out);
        assert_bits_eq(&gemm_ref, &gemm_out, &format!("grouped gemm threads={t}"));

        let mut assign = Vec::new();
        let mut inertia = vec![0f32; groups];
        argmin_dist_groups_threads(t, &x, &centers, d, k, groups, &mut assign, &mut inertia);
        assert_eq!(assign_ref, assign, "grouped argmin assign threads={t}");
        assert_bits_eq(&inertia_ref, &inertia, &format!("grouped inertia threads={t}"));

        let mut sums = vec![0f32; groups * k * d];
        let mut counts = vec![0f32; groups * k];
        scatter_add_groups_threads(t, &x, &assign, d, k, groups, &mut sums, &mut counts);
        assert_bits_eq(&sums_ref, &sums, &format!("grouped sums threads={t}"));
        assert_bits_eq(&counts_ref, &counts, &format!("grouped counts threads={t}"));
    }
}

/// Every registered learner: `local_step_batch` over E edges with
/// distinct models must be bit-identical to E sequential `local_step`
/// calls on the per-edge slices — params AND signals, compounded over
/// several iterations so any drift would amplify.
#[test]
fn local_step_batch_matches_sequential_steps_per_task() {
    let engine = NativeEngine::default();
    let e = 5usize;
    for (name, _about) in registered_tasks() {
        let spec = TaskSpec::parse(name).unwrap();
        let learner = spec.learner();
        let mut rng = Rng::new(9);
        let ds = Arc::new(learner.synth(2048, 2.5, &mut rng));
        let mut shard = partition::iid(&ds, 1, &mut rng).remove(0);
        let hyper = Hyper::default();
        let mut params_seq: Vec<Vec<f32>> =
            (0..e).map(|_| learner.init_params(&ds, &mut rng)).collect();
        let mut params_batch = params_seq.clone();
        let (mut xbuf, mut ybuf) = (Vec::new(), Vec::new());
        let (mut xall, mut yall) = (Vec::new(), Vec::new());
        for iter in 0..3 {
            xall.clear();
            yall.clear();
            for _ in 0..e {
                shard.next_batch(learner.batch(), &mut xbuf, &mut ybuf);
                xall.extend_from_slice(&xbuf);
                yall.extend_from_slice(&ybuf);
            }
            assert_eq!(xall.len() % e, 0, "{name}: uneven x draw");
            assert_eq!(yall.len() % e, 0, "{name}: uneven y draw");
            let (px, py) = (xall.len() / e, yall.len() / e);

            let mut seq_signals = Vec::with_capacity(e);
            for g in 0..e {
                let out = learner
                    .local_step(
                        &engine,
                        &mut params_seq[g],
                        &xall[g * px..(g + 1) * px],
                        &yall[g * py..(g + 1) * py],
                        &hyper,
                    )
                    .unwrap();
                seq_signals.push(out.signal);
            }

            let mut refs: Vec<&mut [f32]> =
                params_batch.iter_mut().map(|p| p.as_mut_slice()).collect();
            let outs = learner
                .local_step_batch(&engine, &mut refs, &xall, &yall, &hyper)
                .unwrap();
            assert_eq!(outs.len(), e, "{name}: batch output count");
            for g in 0..e {
                assert_eq!(
                    seq_signals[g].to_bits(),
                    outs[g].signal.to_bits(),
                    "{name}: signal diverged, edge {g} iter {iter}"
                );
            }
        }
        for g in 0..e {
            assert_bits_eq(
                &params_seq[g],
                &params_batch[g],
                &format!("{name}: params edge {g}"),
            );
        }
    }
}

/// The batch path must stay bit-identical when the kernel pool fans out.
#[test]
fn local_step_batch_bit_identical_under_threads() {
    let engine = NativeEngine::default();
    let e = 6usize;
    for (name, _about) in registered_tasks() {
        let learner = TaskSpec::parse(name).unwrap().learner();
        let mut rng = Rng::new(21);
        let ds = Arc::new(learner.synth(2048, 2.5, &mut rng));
        let mut shard = partition::iid(&ds, 1, &mut rng).remove(0);
        let hyper = Hyper::default();
        let base: Vec<Vec<f32>> = (0..e).map(|_| learner.init_params(&ds, &mut rng)).collect();
        let (mut xbuf, mut ybuf) = (Vec::new(), Vec::new());
        let (mut xall, mut yall) = (Vec::new(), Vec::new());
        for _ in 0..e {
            shard.next_batch(learner.batch(), &mut xbuf, &mut ybuf);
            xall.extend_from_slice(&xbuf);
            yall.extend_from_slice(&ybuf);
        }
        let run = |threads: usize| {
            pool::set_threads(threads);
            let mut params = base.clone();
            let mut refs: Vec<&mut [f32]> =
                params.iter_mut().map(|p| p.as_mut_slice()).collect();
            let outs = learner
                .local_step_batch(&engine, &mut refs, &xall, &yall, &hyper)
                .unwrap();
            pool::set_threads(1);
            let signals: Vec<u64> = outs.iter().map(|o| o.signal.to_bits()).collect();
            (params, signals)
        };
        let (p1, s1) = run(1);
        for t in THREADS {
            let (pt, st) = run(t);
            assert_eq!(s1, st, "{name}: signals diverged at threads={t}");
            for g in 0..e {
                assert_bits_eq(&p1[g], &pt[g], &format!("{name}: params t={t} edge {g}"));
            }
        }
    }
}
