//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image resolves dependencies from vendored paths only, so the
//! real crate cannot be fetched. This shim is source-compatible with the
//! narrow surface the workspace uses:
//!
//! * [`Error`] — a string-backed error with a chain of context frames;
//! * [`Result<T>`] — `Result` defaulted to that error type;
//! * [`anyhow!`] — ad-hoc error construction from a message, a format
//!   string, or any `Display` value;
//! * [`bail!`] — early-return an [`anyhow!`] error;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error`; that is what permits the blanket
//! `From<E: std::error::Error>` conversion powering `?`.

use std::fmt;

/// A string-backed error with outer context frames (most recent first).
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Construct from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            context: Vec::new(),
        }
    }

    /// Wrap a concrete error value.
    pub fn new<E: std::error::Error>(e: E) -> Error {
        Error::msg(e)
    }

    /// Attach an outer context frame.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.context.push(c.to_string());
        self
    }

    fn render(&self) -> String {
        let mut parts: Vec<&str> = self.context.iter().rev().map(|s| s.as_str()).collect();
        parts.push(&self.msg);
        parts.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message literal (with inline captures), a
/// single printable expression, or a format string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context frame to the error.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;

    /// Attach a lazily-evaluated context frame to the error.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn macro_forms() {
        let lit = anyhow!("plain message");
        assert_eq!(lit.to_string(), "plain message");
        let v = 3;
        let inline = anyhow!("value {v}");
        assert_eq!(inline.to_string(), "value 3");
        let fmt = anyhow!("value {}", 7);
        assert_eq!(fmt.to_string(), "value 7");
        let from_expr = anyhow!(String::from("owned"));
        assert_eq!(from_expr.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err())
            .context("reading manifest")
            .map_err(|e| e.context("opening artifacts"));
        assert_eq!(
            e.unwrap_err().to_string(),
            "opening artifacts: reading manifest: missing"
        );
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        let lazy: Option<u8> = None;
        assert!(lazy.with_context(|| format!("{}", 1)).is_err());
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u8> {
            if fail {
                bail!("boom {}", 1);
            }
            Ok(9)
        }
        assert_eq!(f(false).unwrap(), 9);
        assert_eq!(f(true).unwrap_err().to_string(), "boom 1");
    }
}
