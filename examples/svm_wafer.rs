//! Supervised Edge Learning scenario (paper §V-A, "wafer images in smart
//! manufacturing"): 8-class SVM over 59-dim features, label-skewed shards
//! across a heterogeneous 5-edge fleet, comparing all four coordination
//! algorithms at the same resource budget — the single-scenario version of
//! the paper's Fig. 3b, driven by the `Experiment::svm_wafer()` preset.
//!
//!     cargo run --release --example svm_wafer [-- --engine pjrt]

use ol4el::coordinator::Experiment;
use ol4el::strategy::StrategySpec;
use ol4el::harness::{build_engine, EngineKind};
use ol4el::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "pjrt" || a == "--engine=pjrt")
        || std::env::args()
            .collect::<Vec<_>>()
            .windows(2)
            .any(|w| w[0] == "--engine" && w[1] == "pjrt");
    let engine = if use_pjrt {
        build_engine(EngineKind::Pjrt, "artifacts")?
    } else {
        build_engine(EngineKind::Native, "artifacts")?
    };

    println!("SVM on wafer-like data: 5 edges, H=6, 5000 ms budget each\n");
    let mut table = Table::new(
        "coordination algorithms at the same budget",
        &["algorithm", "final acc", "global updates", "mean spent (ms)", "tau mode"],
    );
    for strategy in [
        StrategySpec::ol4el_sync(),
        StrategySpec::ol4el_async(),
        StrategySpec::ac_sync(),
        StrategySpec::fixed_i(),
    ] {
        // The preset carries the whole paper scenario; only the strategy
        // under comparison changes per run.
        let r = Experiment::svm_wafer()
            .strategy(strategy.clone())
            .run(engine.as_ref())?;
        // Most-pulled interval = the policy's revealed preference.
        let tau_mode = r
            .tau_histogram
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i + 1)
            .unwrap_or(0);
        table.row(vec![
            strategy.label(),
            f(r.final_metric, 4),
            r.total_updates.to_string(),
            f(r.mean_spent, 0),
            format!("τ={tau_mode}"),
        ]);
    }
    print!("{}", table.render());
    println!("\nNote how OL4EL-async sustains update volume under heterogeneity while");
    println!("the synchronous policies pay the straggler at every barrier (paper Fig. 3).");
    Ok(())
}
