//! The network layer end to end: (1) a real SVM training run whose
//! edge↔cloud traffic crosses a lossy heavy-tailed WAN while edges crash
//! and restart — the bandit pays for every wire millisecond — and (2) the
//! same protocol at 2000 edges with the engine-free [`FleetSim`].
//!
//!     cargo run --release --example fleet_churn

use std::cell::Cell;
use std::rc::Rc;

use ol4el::config::RunConfig;
use ol4el::coordinator::{observer, Experiment, RunEvent};
use ol4el::engine::native::NativeEngine;
use ol4el::model::TaskSpec;
use ol4el::net::{ChurnSpec, FleetSim, NetworkSpec};
use ol4el::strategy::StrategySpec;

fn main() -> anyhow::Result<()> {
    // -- 1. Real training over a bad network with churn --------------------
    let engine = NativeEngine::default();
    let drops = Rc::new(Cell::new(0u32));
    let churn_events = Rc::new(Cell::new(0u32));
    let (d2, c2) = (drops.clone(), churn_events.clone());
    let result = Experiment::svm_wafer()
        .strategy(StrategySpec::ol4el_async())
        .budget(3000.0)
        .network(NetworkSpec::parse("lognormal:10:0.6,drop:0.05").expect("spec"))
        .churn(ChurnSpec::parse("poisson:0.2,restart:500").expect("spec"))
        .observe(observer::from_fn(move |ev: &RunEvent| match ev {
            RunEvent::MessageDropped { attempts, .. } => d2.set(d2.get() + attempts),
            RunEvent::EdgeJoined { .. } | RunEvent::EdgeRetired { .. } => {
                c2.set(c2.get() + 1)
            }
            _ => {}
        }))
        .run(&engine)?;
    println!(
        "WAN training: accuracy {:.4} after {} updates ({} dropped attempts, {} churn events)",
        result.final_metric,
        result.total_updates,
        drops.get(),
        churn_events.get()
    );

    // Baseline: same run over the ideal network, no churn.
    let ideal = Experiment::svm_wafer()
        .strategy(StrategySpec::ol4el_async())
        .budget(3000.0)
        .run(&engine)?;
    println!(
        "ideal network: accuracy {:.4} after {} updates — the network's price is {} updates\n",
        ideal.final_metric,
        ideal.total_updates,
        ideal.total_updates.saturating_sub(result.total_updates)
    );

    // -- 2. The same protocol at 2000 edges (engine-free) ------------------
    let cfg = RunConfig {
        task: TaskSpec::svm(), // ignored: the fleet trains no model
        strategy: StrategySpec::ol4el_async(),
        n_edges: 2000,
        hetero: 6.0,
        budget: 3000.0,
        eval_every: 500,
        network: NetworkSpec::parse("lognormal:20:0.8,drop:0.02").expect("spec"),
        churn: ChurnSpec::parse("poisson:0.05,join:0.1,restart:2000").expect("spec"),
        ..Default::default()
    };
    let report = FleetSim::new(cfg)?.run()?;
    println!(
        "fleet 2000: {} updates in {:.1}s virtual ({} joined, {} retired, {} msgs lost)",
        report.updates,
        report.wall_ms / 1000.0,
        report.joined,
        report.retired,
        report.messages_lost
    );
    println!(
        "kernel: {} events at {:.2} M/s, peak queue {} [{:.2}s host]",
        report.events,
        report.events_per_sec() / 1e6,
        report.peak_queue_depth,
        report.host_seconds
    );
    Ok(())
}
