//! Scalability scenario (paper §V-B.3 / Fig. 5): grow the fleet from 3 to
//! 50 edge servers at two heterogeneity levels and watch OL4EL-async's
//! accuracy improve with N while OL4EL-sync pays the straggler.
//!
//!     cargo run --release --example fleet_scale

use ol4el::config::{Algo, RunConfig};
use ol4el::coordinator;
use ol4el::harness::{build_engine, EngineKind};
use ol4el::model::Task;
use ol4el::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let engine = build_engine(EngineKind::Native, "artifacts")?;
    let t0 = std::time::Instant::now();

    let mut table = Table::new(
        "fleet scaling (SVM accuracy, budget 3000 ms/edge)",
        &["N", "async H=1", "async H=10", "sync H=1", "sync H=10", "async updates H=10"],
    );
    for n in [3usize, 10, 25, 50] {
        let mut row = vec![n.to_string()];
        let mut async_updates = 0u64;
        for algo in [Algo::Ol4elAsync, Algo::Ol4elSync] {
            for h in [1.0f64, 10.0] {
                let cfg = RunConfig {
                    task: Task::Svm,
                    algo,
                    n_edges: n,
                    hetero: h,
                    budget: 3000.0,
                    data_n: 12_000.max(n * 100),
                    seed: 5,
                    ..Default::default()
                }
                .with_paper_utility();
                let r = coordinator::run(&cfg, engine.as_ref())?;
                row.push(f(r.final_metric, 4));
                if algo == Algo::Ol4elAsync && h == 10.0 {
                    async_updates = r.total_updates;
                }
            }
        }
        row.push(async_updates.to_string());
        table.row(row);
    }
    print!("{}", table.render());
    println!(
        "\nMore edges aggregate more information per unit time; the async pattern\n\
         converts that into accuracy even at H=10 (paper Fig. 5). [{:.1}s]",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
