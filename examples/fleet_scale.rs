//! Scalability scenario (paper §V-B.3 / Fig. 5): grow the fleet from 3 to
//! 50 edge servers at two heterogeneity levels and watch OL4EL-async's
//! accuracy improve with N while OL4EL-sync pays the straggler — expressed
//! as one declarative `ExperimentSuite` grid executed on worker threads.
//!
//!     cargo run --release --example fleet_scale

use ol4el::config::RunConfig;
use ol4el::coordinator::{find_outcome, ExperimentSuite};
use ol4el::model::TaskSpec;
use ol4el::strategy::StrategySpec;
use ol4el::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();

    let base = RunConfig {
        task: TaskSpec::svm(),
        budget: 3000.0,
        seed: 5,
        ..Default::default()
    };
    // 4 fleet sizes x 2 heterogeneity levels x 2 manners = 16 cells, each
    // a full training run — the suite fans them out across workers and
    // returns outcomes in deterministic cell order.
    let suite = ExperimentSuite::new("fleet-scale", base)
        .strategies([StrategySpec::ol4el_async(), StrategySpec::ol4el_sync()])
        .fleet_sizes([3, 10, 25, 50])
        .heteros([1.0, 10.0])
        .configure(|cfg| {
            cfg.data_n = 12_000.max(cfg.n_edges * 100);
            *cfg = cfg.clone().with_paper_utility();
        });
    let outcomes = suite.run_native()?;

    let mut table = Table::new(
        "fleet scaling (SVM accuracy, budget 3000 ms/edge)",
        &["N", "async H=1", "async H=10", "sync H=1", "sync H=10", "async updates H=10"],
    );
    for n in [3usize, 10, 25, 50] {
        let mut row = vec![n.to_string()];
        for strategy in [StrategySpec::ol4el_async(), StrategySpec::ol4el_sync()] {
            for h in [1.0f64, 10.0] {
                let out = find_outcome(&outcomes, &TaskSpec::svm(), &strategy, n, h)
                    .expect("suite covers the full grid");
                row.push(f(out.agg.metric.mean(), 4));
            }
        }
        let async_h10 =
            find_outcome(&outcomes, &TaskSpec::svm(), &StrategySpec::ol4el_async(), n, 10.0)
                .unwrap();
        row.push(format!("{:.0}", async_h10.agg.updates.mean()));
        table.row(row);
    }
    print!("{}", table.render());
    println!(
        "\nMore edges aggregate more information per unit time; the async pattern\n\
         converts that into accuracy even at H=10 (paper Fig. 5). [{:.1}s]",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
