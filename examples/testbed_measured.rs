//! Testbed-mode scenario (paper §V-A "testbed experiments"): resource
//! costs are the MEASURED wall-clock of real PJRT executions of the AOT
//! HLO artifacts, scaled by each edge's heterogeneity multiplier — the
//! in-process analogue of the paper's three-mini-PC docker testbed, driven
//! by the `Experiment::testbed()` preset. Requires `make artifacts`.
//!
//!     cargo run --release --example testbed_measured

use ol4el::coordinator::Experiment;
use ol4el::strategy::StrategySpec;
use ol4el::harness::{build_engine, EngineKind};
use ol4el::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let engine = match build_engine(EngineKind::Pjrt, "artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("testbed_measured needs the AOT artifacts: {e}");
            eprintln!("run `make artifacts` first");
            std::process::exit(2);
        }
    };

    // Measured costs: budgets are real milliseconds of (scaled) compute.
    // PJRT CPU steps run ~fractions of a ms, so the preset's small budget
    // suffices.
    println!("Testbed mode: measured PJRT wall-clock as the resource meter\n");
    let mut table = Table::new(
        "measured-cost testbed (SVM, 3 edges, H=6, 150 ms budget)",
        &["algorithm", "final acc", "updates", "mean spent (ms)", "host s"],
    );
    for strategy in [StrategySpec::ol4el_sync(), StrategySpec::ol4el_async()] {
        let t0 = std::time::Instant::now();
        let r = Experiment::testbed()
            .strategy(strategy.clone())
            .run(engine.as_ref())?;
        table.row(vec![
            strategy.label(),
            f(r.final_metric, 4),
            r.total_updates.to_string(),
            f(r.mean_spent, 1),
            f(t0.elapsed().as_secs_f64(), 2),
        ]);
    }
    print!("{}", table.render());
    println!("\nEvery local iteration above executed the Pallas-lowered HLO via PJRT;");
    println!("costs charged to each edge are its measured step times x its slowdown.");
    Ok(())
}
