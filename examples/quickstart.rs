//! Quickstart: the full three-layer path end to end.
//!
//! Loads the AOT HLO artifacts (JAX L2 + Pallas L1, built by
//! `make artifacts`) into the PJRT CPU client, assembles a 3-edge
//! heterogeneous fleet, and trains the paper's SVM task with OL4EL-async —
//! printing the metric trace and the bandit's learned interval preferences.
//!
//!     make artifacts && cargo run --release --example quickstart

use ol4el::config::{Algo, RunConfig};
use ol4el::coordinator;
use ol4el::harness::{build_engine, EngineKind};
use ol4el::model::Task;

fn main() -> anyhow::Result<()> {
    // The production engine: HLO artifacts on PJRT. Falls back to the
    // native oracle with a warning if artifacts are missing, so the example
    // always runs.
    let (engine, engine_name) = match build_engine(EngineKind::Pjrt, "artifacts") {
        Ok(e) => (e, "pjrt (AOT HLO artifacts)"),
        Err(err) => {
            eprintln!("! artifacts not found ({err}); falling back to the native engine");
            eprintln!("  run `make artifacts` to exercise the full three-layer path\n");
            (build_engine(EngineKind::Native, "artifacts")?, "native")
        }
    };

    let cfg = RunConfig {
        task: Task::Svm,
        algo: Algo::Ol4elAsync,
        n_edges: 3,
        hetero: 6.0,   // fastest edge 6x the slowest — the Fig. 4 regime
        budget: 2500.0,
        data_n: 8_000,
        seed: 42,
        ..Default::default()
    };

    println!("OL4EL quickstart");
    println!("  engine : {engine_name}");
    println!(
        "  task   : {} ({} classes x {} features, wafer-like)",
        cfg.task.name(),
        engine.shapes().svm_c,
        engine.shapes().svm_d
    );
    println!(
        "  fleet  : {} edges, heterogeneity H={}, budget {} ms each",
        cfg.n_edges, cfg.hetero, cfg.budget
    );
    println!("  algo   : {} (per-edge budget-limited bandits)\n", cfg.algo.name());

    let t0 = std::time::Instant::now();
    let result = coordinator::run(&cfg, engine.as_ref())?;

    println!("trace (virtual ms -> test accuracy):");
    let stride = (result.trace.len() / 12).max(1);
    for p in result.trace.iter().step_by(stride) {
        println!(
            "  t={:>7.0}ms  spent={:>6.0}ms  updates={:>4}  acc={:.4}",
            p.wall_ms, p.mean_spent, p.updates, p.metric
        );
    }
    println!(
        "\nfinal accuracy {:.4} after {} global updates ({} edges retired, host {:.1}s)",
        result.final_metric,
        result.total_updates,
        result.retired_edges,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "interval pulls (τ=1..{}): {:?}",
        result.tau_histogram.len(),
        result.tau_histogram
    );
    println!("\nNext: examples/svm_wafer.rs, examples/kmeans_traffic.rs, `cargo bench`");
    Ok(())
}
