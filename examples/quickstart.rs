//! Quickstart: the full three-layer path end to end, through the
//! `Experiment` builder API.
//!
//! Loads the AOT HLO artifacts (JAX L2 + Pallas L1, built by
//! `make artifacts`) into the PJRT CPU client, assembles a 3-edge
//! heterogeneous fleet, and trains the paper's SVM task with OL4EL-async —
//! streaming the metric trace live via an `Observer` and printing the
//! bandit's learned interval preferences at the end.
//!
//!     make artifacts && cargo run --release --example quickstart

use ol4el::coordinator::{observer, Experiment, RunEvent};
use ol4el::strategy::StrategySpec;
use ol4el::harness::{build_engine, EngineKind};
use ol4el::model::{Learner as _, TaskSpec};

fn main() -> anyhow::Result<()> {
    // The production engine: HLO artifacts on PJRT. Falls back to the
    // native oracle with a warning if artifacts are missing, so the example
    // always runs.
    let (engine, engine_name) = match build_engine(EngineKind::Pjrt, "artifacts") {
        Ok(e) => (e, "pjrt (AOT HLO artifacts)"),
        Err(err) => {
            eprintln!("! artifacts not found ({err}); falling back to the native engine");
            eprintln!("  run `make artifacts` to exercise the full three-layer path\n");
            (build_engine(EngineKind::Native, "artifacts")?, "native")
        }
    };

    let exp = Experiment::builder()
        .task(TaskSpec::svm())
        .strategy(StrategySpec::ol4el_async())
        .edges(3)
        .hetero(6.0) // fastest edge 6x the slowest — the Fig. 4 regime
        .budget(2500.0)
        .data_n(8_000)
        .seed(42)
        // Streaming observer: watch the run as it happens instead of
        // post-processing a trace. Every 25th update keeps output short.
        .observe(observer::from_fn(|ev: &RunEvent| match ev {
            RunEvent::GlobalUpdate { point } if point.updates % 25 == 0 => println!(
                "  t={:>7.0}ms  spent={:>6.0}ms  updates={:>4}  acc={:.4}",
                point.wall_ms, point.mean_spent, point.updates, point.metric
            ),
            RunEvent::EdgeRetired { edge, wall_ms, .. } => {
                println!("  edge {edge} retired its budget at t={wall_ms:>7.0}ms")
            }
            _ => {}
        }))
        .build()?;

    println!("OL4EL quickstart");
    println!("  engine : {engine_name}");
    let learner = exp.config().task.learner();
    println!(
        "  task   : {} ({} parameters, wafer-like data)",
        exp.config().task.name(),
        learner.param_len()
    );
    println!(
        "  fleet  : {} edges, heterogeneity H={}, budget {} ms each",
        exp.config().n_edges,
        exp.config().hetero,
        exp.config().budget
    );
    println!(
        "  strategy: {} (per-edge budget-limited bandits)\n",
        exp.config().strategy.label()
    );
    println!("live trace (virtual ms -> test accuracy):");

    let t0 = std::time::Instant::now();
    let result = exp.run(engine.as_ref())?;

    println!(
        "\nfinal accuracy {:.4} after {} global updates ({} edges retired, host {:.1}s)",
        result.final_metric,
        result.total_updates,
        result.retired_edges,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "interval pulls (τ=1..{}): {:?}",
        result.tau_histogram.len(),
        result.tau_histogram
    );
    println!("\nNext: examples/svm_wafer.rs, examples/kmeans_traffic.rs, `cargo bench`");
    Ok(())
}
