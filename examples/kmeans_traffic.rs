//! Unsupervised Edge Learning scenario (paper §V-A, "traffic images
//! clipped from surveillance videos", K=3): distributed mini-batch K-means
//! across edges with a *variable* resource-cost environment — the §IV-B.2
//! regime where OL4EL must learn arm costs online (UCB-BV) — driven by the
//! `Experiment::kmeans_traffic()` preset.
//!
//!     cargo run --release --example kmeans_traffic

use ol4el::coordinator::Experiment;
use ol4el::strategy::StrategySpec;
use ol4el::harness::{build_engine, EngineKind};
use ol4el::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let engine = build_engine(EngineKind::Native, "artifacts")?;

    println!("K-means on traffic-like data (K=3), variable resource costs (cv=0.35)\n");

    // The §IV-B.2 comparison: a bandit that assumes fixed costs (KUBE)
    // versus one that explores costs (UCB-BV) in a variable-cost world.
    let mut table = Table::new(
        "variable-cost world: cost-aware vs cost-assuming bandits",
        &["bandit", "final F1", "global updates", "mean spent (ms)"],
    );
    for bandit in ["ucb-bv", "kube"] {
        let r = Experiment::kmeans_traffic()
            .strategy(StrategySpec::parse(&format!("ol4el:bandit={bandit}"))?)
            .run(engine.as_ref())?;
        table.row(vec![
            bandit.to_string(),
            f(r.final_metric, 4),
            r.total_updates.to_string(),
            f(r.mean_spent, 0),
        ]);
    }
    print!("{}", table.render());

    // Show the learned interval distribution of the preset's default
    // (auto-resolved to UCB-BV under variable costs).
    let r = Experiment::kmeans_traffic().run(engine.as_ref())?;
    println!("\nUCB-BV interval pulls (τ=1..{}):", r.tau_histogram.len());
    let max = r.tau_histogram.iter().copied().max().unwrap_or(1).max(1);
    for (i, &c) in r.tau_histogram.iter().enumerate() {
        let bar = "#".repeat((c * 40 / max) as usize);
        println!("  τ={:<2} {:>5}  {bar}", i + 1, c);
    }
    println!("\nfinal F1 {:.4} after {} merges", r.final_metric, r.total_updates);
    Ok(())
}
